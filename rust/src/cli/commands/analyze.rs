//! `networks` and `analyze`: zoo inspection and per-layer partitioning.

use anyhow::{anyhow, Result};

use crate::analytics::bandwidth::ControllerMode;
use crate::analytics::partition::Strategy;
use crate::api::{Engine, Request, Response};
use crate::cli::args::Args;
use crate::config::accel::{parse_mode, parse_strategy};
use crate::models::zoo;
use crate::util::tablefmt::{mact, Table};

pub(crate) fn mode_from(args: &Args) -> Result<ControllerMode> {
    args.opt("mode").map(parse_mode).transpose().map(|m| m.unwrap_or(ControllerMode::Passive))
}

pub(crate) fn strategy_from(args: &Args) -> Result<Strategy> {
    args.opt("strategy")
        .map(parse_strategy)
        .transpose()
        .map(|s| s.unwrap_or(Strategy::Optimal))
}

/// Shared `--bits` parsing (single precision), alongside
/// `mode_from`/`strategy_from` so every subcommand accepts the same
/// spellings. `None` when the flag is absent.
pub(crate) fn opt_bits_from(args: &Args) -> Result<Option<crate::models::DataTypes>> {
    args.opt("bits").map(crate::models::DataTypes::parse).transpose()
}

/// `psim networks` — the zoo at a glance.
pub fn networks(args: &Args) -> Result<i32> {
    let faithful = args.flag("faithful");
    let csv = args.flag("csv");
    args.reject_unknown()?;
    let nets = if faithful { zoo::faithful_networks() } else { zoo::paper_networks() };
    let mut t = Table::new(vec!["CNN", "conv layers", "MACs (G)", "weights (M)", "min BW (M act)"]);
    for net in nets.iter().chain(zoo::extra_networks().iter()) {
        t.row(vec![
            net.name.clone(),
            net.layers.len().to_string(),
            format!("{:.2}", net.total_macs() as f64 / 1e9),
            format!("{:.2}", net.total_weights() as f64 / 1e6),
            mact(net.min_bandwidth() as f64, 3),
        ]);
    }
    if csv {
        print!("{}", t.to_csv());
    } else {
        print!("{}", t.to_markdown());
    }
    Ok(0)
}

/// `psim analyze --network NAME --macs P [--strategy S] [--mode M]
/// [--bits 8:8:32:8]`.
pub fn analyze(args: &Args) -> Result<i32> {
    let name = args.opt("network").ok_or_else(|| anyhow!("--network is required"))?.to_string();
    let p_macs = args.opt_usize("macs")?.unwrap_or(2048);
    let mode = mode_from(args)?;
    let strategy = strategy_from(args)?;
    let dt = opt_bits_from(args)?.unwrap_or_default();
    let csv = args.flag("csv");
    args.reject_unknown()?;

    let net = zoo::by_name(&name)
        .ok_or_else(|| anyhow!("unknown network '{name}' — see `psim networks`"))?;
    // Same facade as `serve` and library callers; the per-layer table is
    // rendered by `report::analyze` from the engine's memoized evaluator.
    let engine = Engine::analytics();
    let resp = engine.dispatch(&Request::Analyze { network: net, p_macs, strategy, mode, dt })?;
    let Response::Table { table, note } = resp else {
        unreachable!("analyze dispatch always returns a table response")
    };
    if csv {
        print!("{}", table.to_csv());
    } else {
        print!("{}", table.to_markdown());
    }
    println!("\n{note}");
    Ok(0)
}
