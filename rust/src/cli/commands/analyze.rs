//! `networks` and `analyze`: zoo inspection and per-layer partitioning.

use anyhow::{anyhow, Result};

use crate::analytics::bandwidth::ControllerMode;
use crate::analytics::grid::GridEngine;
use crate::analytics::optimizer;
use crate::analytics::partition::Strategy;
use crate::cli::args::Args;
use crate::config::accel::{parse_mode, parse_strategy};
use crate::models::zoo;
use crate::util::tablefmt::{mact, Table};

pub(crate) fn mode_from(args: &Args) -> Result<ControllerMode> {
    args.opt("mode").map(parse_mode).transpose().map(|m| m.unwrap_or(ControllerMode::Passive))
}

pub(crate) fn strategy_from(args: &Args) -> Result<Strategy> {
    args.opt("strategy")
        .map(parse_strategy)
        .transpose()
        .map(|s| s.unwrap_or(Strategy::Optimal))
}

/// `psim networks` — the zoo at a glance.
pub fn networks(args: &Args) -> Result<i32> {
    let faithful = args.flag("faithful");
    let csv = args.flag("csv");
    args.reject_unknown()?;
    let nets = if faithful { zoo::faithful_networks() } else { zoo::paper_networks() };
    let mut t = Table::new(vec!["CNN", "conv layers", "MACs (G)", "weights (M)", "min BW (M act)"]);
    for net in nets.iter().chain(zoo::extra_networks().iter()) {
        t.row(vec![
            net.name.clone(),
            net.layers.len().to_string(),
            format!("{:.2}", net.total_macs() as f64 / 1e9),
            format!("{:.2}", net.total_weights() as f64 / 1e6),
            mact(net.min_bandwidth() as f64, 3),
        ]);
    }
    if csv {
        print!("{}", t.to_csv());
    } else {
        print!("{}", t.to_markdown());
    }
    Ok(0)
}

/// `psim analyze --network NAME --macs P [--strategy S] [--mode M]`.
pub fn analyze(args: &Args) -> Result<i32> {
    let name = args.opt("network").ok_or_else(|| anyhow!("--network is required"))?.to_string();
    let p_macs = args.opt_usize("macs")?.unwrap_or(2048);
    let mode = mode_from(args)?;
    let strategy = strategy_from(args)?;
    let csv = args.flag("csv");
    args.reject_unknown()?;

    let net = zoo::by_name(&name)
        .ok_or_else(|| anyhow!("unknown network '{name}' — see `psim networks`"))?;
    let mut t = Table::new(vec![
        "layer", "shape", "m", "n", "m* (eq.7)", "MAC util", "B_i (M)", "B_o (M)", "B (M)",
    ]);
    // Per-layer rows come from the sweep engine's memoized evaluator, so
    // repeated shapes (ResNet blocks, VGG stacks) are computed once.
    let engine = GridEngine::new();
    let mut total = 0.0;
    for layer in &net.layers {
        let eval = engine.layer_eval(layer, p_macs, strategy, mode);
        let (part, bw) = (eval.partition, eval.bandwidth);
        let m_star = optimizer::optimal_m_real(layer, p_macs, mode);
        total += bw.total();
        t.row(vec![
            layer.name.clone(),
            format!("{}x{}x{}→{}x{}x{} k{}{}",
                layer.wi, layer.hi, layer.m, layer.wo(), layer.ho(), layer.n, layer.k,
                if layer.groups > 1 { format!(" g{}", layer.groups) } else { String::new() }),
            part.m.to_string(),
            part.n.to_string(),
            format!("{m_star:.2}"),
            format!("{:.0}%", (layer.k * layer.k * part.m * part.n) as f64 / p_macs as f64 * 100.0),
            mact(bw.input, 2),
            mact(bw.output, 2),
            mact(bw.total(), 2),
        ]);
    }
    if csv {
        print!("{}", t.to_csv());
    } else {
        print!("{}", t.to_markdown());
    }
    println!(
        "\n{} @ P={p_macs}, {} controller, {} strategy: total {} M activations \
         (floor {} M)",
        net.name,
        mode.label(),
        strategy.label(),
        mact(total, 2),
        mact(net.min_bandwidth() as f64, 3),
    );
    Ok(0)
}
