//! `explore`: the design-space explorer from the CLI — Pareto frontiers
//! over MAC budget × SRAM capacity × strategy × controller mode, as
//! deterministic JSONL (or a markdown table with `--table`).

use std::time::Instant;

use anyhow::{Context, Result};

use crate::analytics::grid::GridEngine;
use crate::cli::args::Args;
use crate::coordinator::parallel::default_workers;
use crate::dse::budget::apply_constraints;
use crate::dse::explore as dse_explore;
use crate::dse::pareto::parse_objectives;
use crate::dse::space::ExploreSpec;
use crate::models::zoo;
use crate::report::frontier;

use super::sweep::resolve_network;

/// `psim explore [--networks a,b]
/// [--constraints macs=512:2048,sram=64k:unlimited,strategies=optimal,modes=active]
/// [--objectives bandwidth,energy] [--workers N] [--out FILE] [--table]
/// [--faithful]`
///
/// Emits one JSON object per Pareto-frontier point (JSONL) on stdout (or
/// `--out`), byte-identical for any `--workers` value; a run summary goes
/// to stderr so stdout stays pipeable.
pub fn explore(args: &Args) -> Result<i32> {
    let faithful = args.flag("faithful");
    let networks = match args.opt("networks") {
        Some(list) => list
            .split(',')
            .map(|raw| resolve_network(raw.trim(), faithful))
            .collect::<Result<Vec<_>>>()?,
        None => {
            if faithful {
                zoo::faithful_networks()
            } else {
                zoo::paper_networks()
            }
        }
    };
    let mut spec = ExploreSpec::new(networks);
    if let Some(text) = args.opt("constraints") {
        apply_constraints(&mut spec, text)?;
    }
    if let Some(list) = args.opt("objectives") {
        spec.objectives = parse_objectives(list)?;
    }
    let workers = args.opt_usize("workers")?.unwrap_or_else(default_workers).max(1);
    let out = args.opt("out").map(std::path::PathBuf::from);
    let table = args.flag("table");
    args.reject_unknown()?;
    spec.validate()?;

    let engine = GridEngine::new();
    let t0 = Instant::now();
    let result = dse_explore::explore(&engine, &spec, workers);
    let elapsed = t0.elapsed();

    let text = if table {
        frontier::frontier_table(&result).to_markdown()
    } else {
        result.to_jsonl()
    };
    match &out {
        Some(path) => {
            std::fs::write(path, &text)
                .with_context(|| format!("writing frontier to {}", path.display()))?;
        }
        None => print!("{text}"),
    }
    let (hits, misses) = engine.cache_stats();
    eprintln!(
        "{}{} in {:.3}s on {workers} workers; layer cache {hits} hits / {misses} misses",
        frontier::summarize(&result),
        out.as_ref().map(|p| format!(" -> {}", p.display())).unwrap_or_default(),
        elapsed.as_secs_f64(),
    );
    Ok(0)
}
