//! `explore`: the design-space explorer from the CLI — Pareto frontiers
//! over MAC budget × SRAM capacity × strategy × controller mode, as
//! deterministic JSONL (or a markdown table with `--table`).

use std::time::Instant;

use anyhow::{Context, Result};

use crate::analytics::fusion;
use crate::api::engine::effective_workers;
use crate::api::{Engine, Request, Response};
use crate::cli::args::Args;
use crate::dse::budget::apply_constraints;
use crate::dse::pareto::parse_objectives;
use crate::dse::space::ExploreSpec;
use crate::models::zoo;
use crate::report::frontier;

use super::sweep::resolve_network;

/// Longest fusable chain across `networks` — the useful upper bound for
/// the `--fusion` depth expansion.
fn max_chain_len(networks: &[crate::models::Network]) -> usize {
    networks
        .iter()
        .flat_map(|n| fusion::chains(n, usize::MAX))
        .map(|r| r.len())
        .max()
        .unwrap_or(1)
}

/// `psim explore [--networks a,b]
/// [--constraints macs=512:2048,sram=64k:unlimited,strategies=optimal,modes=active]
/// [--objectives bandwidth,energy] [--fusion [D]] [--bits 8:8:32:8]
/// [--workers N] [--out FILE] [--table] [--faithful]`
///
/// `--bits` prices the exploration under a per-tensor precision
/// (`ifmap:weight:psum:ofmap` bits); pair it with
/// `--objectives bandwidth-bytes,...` to put byte traffic on the
/// frontier.
///
/// `--fusion` adds the inter-layer fusion axis: bare, it explores depths
/// 1–2; with a value `D`, depths 1..=D (so fused and unfused candidates
/// compete on the same frontier). Either form is capped at the longest
/// fusable chain of the selected networks — deeper candidates would be
/// byte-identical duplicates. `--constraints fusion=...` overrides both
/// with an explicit depth list.
///
/// Emits one JSON object per Pareto-frontier point (JSONL) on stdout (or
/// `--out`), byte-identical for any `--workers` value; a run summary goes
/// to stderr so stdout stays pipeable.
pub fn explore(args: &Args) -> Result<i32> {
    let faithful = args.flag("faithful");
    let networks = match args.opt("networks") {
        Some(list) => list
            .split(',')
            .map(|raw| resolve_network(raw.trim(), faithful))
            .collect::<Result<Vec<_>>>()?,
        None => {
            if faithful {
                zoo::faithful_networks()
            } else {
                zoo::paper_networks()
            }
        }
    };
    let mut spec = ExploreSpec::new(networks);
    if let Some(depth) = args.opt_usize("fusion")? {
        anyhow::ensure!(depth >= 1, "--fusion depth must be >= 1");
        // Depths beyond the longest fusable chain evaluate to identical
        // candidates (equal objective vectors all survive Pareto), so cap
        // the expansion at the useful maximum.
        spec.fusion_depths = (1..=depth.min(max_chain_len(&spec.networks))).collect();
    } else if args.flag("fusion") {
        spec.fusion_depths = (1..=max_chain_len(&spec.networks).min(2)).collect();
    }
    if let Some(text) = args.opt("constraints") {
        apply_constraints(&mut spec, text)?;
    }
    if let Some(list) = args.opt("objectives") {
        spec.objectives = parse_objectives(list)?;
    }
    if let Some(dt) = super::analyze::opt_bits_from(args)? {
        spec.datatypes = dt;
    }
    let workers = effective_workers(args.opt_usize("workers")?);
    let out = args.opt("out").map(std::path::PathBuf::from);
    let table = args.flag("table");
    args.reject_unknown()?;

    // Same facade as `serve` and library callers: validation, the
    // request-size cap and the worker clamp all live in the dispatcher.
    let engine = Engine::analytics();
    let t0 = Instant::now();
    let resp = engine.dispatch(&Request::Explore { spec, workers: Some(workers) })?;
    let elapsed = t0.elapsed();
    let Response::Explore { result } = resp else {
        unreachable!("explore dispatch always returns an explore response")
    };

    let text = if table {
        frontier::frontier_table(&result).to_markdown()
    } else {
        result.to_jsonl()
    };
    match &out {
        Some(path) => {
            std::fs::write(path, &text)
                .with_context(|| format!("writing frontier to {}", path.display()))?;
        }
        None => print!("{text}"),
    }
    let (hits, misses) = engine.cache_stats();
    eprintln!(
        "{}{} in {:.3}s on {workers} workers; layer cache {hits} hits / {misses} misses",
        frontier::summarize(&result),
        out.as_ref().map(|p| format!(" -> {}", p.display())).unwrap_or_default(),
        elapsed.as_secs_f64(),
    );
    Ok(0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::zoo;

    #[test]
    fn fusion_depth_expansion_caps_at_longest_chain() {
        // AlexNet's longest fusable chain is conv3 -> conv4 -> conv5;
        // VGG-16's stacks also top out at three layers.
        assert_eq!(max_chain_len(&[zoo::alexnet()]), 3);
        assert_eq!(max_chain_len(&[zoo::alexnet(), zoo::vgg16()]), 3);
    }
}
