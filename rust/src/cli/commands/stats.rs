//! `stats`: one-shot snapshot of a running server's observability
//! registry — connect, send `{"cmd":"stats"}`, print the reply.
//!
//! The raw JSON line goes to stdout (pipe it to `jq` or a scraper); a
//! short human digest goes to stderr. `psim bench --stats` reuses
//! [`fetch`] to report the queue-wait vs compute split after a load run.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::cli::args::Args;
use crate::util::json::Json;

/// Fetch one `{"cmd":"stats"}` snapshot from the server on `port`.
pub fn fetch(port: u16) -> Result<Json> {
    let mut writer = TcpStream::connect(("127.0.0.1", port))
        .with_context(|| format!("connecting to 127.0.0.1:{port} — is `psim serve` running?"))?;
    writer.set_read_timeout(Some(Duration::from_secs(30)))?;
    let mut reader = BufReader::new(writer.try_clone()?);
    let line = r#"{"cmd":"stats"}"#;
    writeln!(writer, "{line}")?;
    let mut reply = String::new();
    if reader.read_line(&mut reply)? == 0 {
        bail!("server closed the connection before replying to stats");
    }
    let snap = Json::parse(reply.trim()).context("unparseable stats reply")?;
    if snap.get("error").is_some() {
        bail!("server rejected the stats request: {snap}");
    }
    Ok(snap)
}

/// Pull one `u64` field out of a snapshot by path, defaulting to 0.
fn field(snap: &Json, path: &[&str]) -> u64 {
    let mut node = snap;
    for key in path {
        match node.get(key) {
            Some(next) => node = next,
            None => return 0,
        }
    }
    node.as_f64().map(|v| v as u64).unwrap_or(0)
}

/// Total microseconds spent inside command dispatch, summed over every
/// `api_latency_us_*` histogram in the snapshot.
fn compute_us(snap: &Json) -> u64 {
    let Some(Json::Obj(hists)) = snap.get("histograms") else {
        return 0;
    };
    hists
        .iter()
        .filter(|(name, _)| name.starts_with("api_latency_us_"))
        .map(|(_, h)| h.get("sum_us").and_then(Json::as_f64).map(|v| v as u64).unwrap_or(0))
        .sum()
}

/// The human digest printed to stderr: reply accounting plus the
/// queue-wait vs compute split the paper's pressure-shaping lesson asks
/// servers to watch.
pub fn human_line(snap: &Json) -> String {
    let replies = field(snap, &["counters", "serve_replies"]);
    let dispatched = field(snap, &["counters", "serve_replies_dispatched"]);
    let coalesced = field(snap, &["counters", "serve_replies_coalesced"]);
    let shed = field(snap, &["counters", "serve_conns_shed"]);
    let errors = field(snap, &["counters", "api_errors"]);
    let queue_us = field(snap, &["histograms", "serve_queue_wait_us", "sum_us"]);
    let queue_p95 = field(snap, &["histograms", "serve_queue_wait_us", "p95_us"]);
    let compute = compute_us(snap);
    format!(
        "psim stats: {replies} replies ({dispatched} dispatched + {coalesced} coalesced), \
         {shed} shed, {errors} errors; queue-wait {queue_us}us total (p95 {queue_p95}us) \
         vs compute {compute}us"
    )
}

/// `psim stats [--port P]` — print one live snapshot and exit.
pub fn stats(args: &Args) -> Result<i32> {
    let port = args.opt_usize("port")?.unwrap_or(7878) as u16;
    args.reject_unknown()?;
    let snap = fetch(port)?;
    println!("{snap}");
    eprintln!("{}", human_line(&snap));
    Ok(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Json {
        Json::parse(concat!(
            r#"{"counters":{"api_errors":1,"serve_conns_shed":2,"serve_replies":10,"#,
            r#""serve_replies_coalesced":3,"serve_replies_dispatched":7},"#,
            r#""histograms":{"api_latency_us_sweep":{"sum_us":400},"#,
            r#""api_latency_us_version":{"sum_us":100},"#,
            r#""serve_queue_wait_us":{"p95_us":9,"sum_us":50}},"protocol":1,"schema":1}"#,
        ))
        .unwrap()
    }

    #[test]
    fn human_line_reports_the_split() {
        let line = human_line(&sample());
        assert!(line.contains("10 replies (7 dispatched + 3 coalesced)"), "{line}");
        assert!(line.contains("2 shed"), "{line}");
        assert!(line.contains("queue-wait 50us total (p95 9us)"), "{line}");
        assert!(line.contains("compute 500us"), "{line}");
    }

    #[test]
    fn missing_fields_default_to_zero() {
        let line = human_line(&Json::parse("{}").unwrap());
        assert!(line.contains("0 replies (0 dispatched + 0 coalesced)"), "{line}");
        assert!(line.contains("compute 0us"), "{line}");
    }

    #[test]
    fn fetch_fails_cleanly_without_a_server() {
        // Port 1 is never listening in the test environment.
        assert!(fetch(1).is_err());
    }
}
