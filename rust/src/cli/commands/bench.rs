//! `bench`: a protocol-level load generator against a running
//! `psim serve`, generalizing the concurrency plumbing `psim infer` uses
//! (same exact client-share split, scoped threads, atomic accounting) to
//! arbitrary protocol command mixes.
//!
//! Each client thread keeps one JSON-lines connection alive and fires
//! its share of requests back-to-back, reconnecting after a `too_busy`
//! shed (the server closes shed connections) or an I/O error. The merged
//! result is printed as one JSON summary line
//! ([`crate::report::bench::SUMMARY_KEYS`]) — the format checked in as
//! `BENCH_serve.json` and schema-validated by CI.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::cli::args::Args;
use crate::coordinator::parallel::split_shares;
use crate::report::bench::BenchRun;
use crate::util::json::Json;

/// Canned request line for one protocol command, sized so a mixed load
/// exercises the engine without any single request dominating the run.
fn canned(cmd: &str) -> Option<&'static str> {
    Some(match cmd {
        "sweep" => concat!(
            r#"{"cmd":"sweep","networks":["AlexNet"],"macs":[512],"#,
            r#""strategies":["optimal"],"modes":["passive"]}"#
        ),
        "explore" => concat!(
            r#"{"cmd":"explore","networks":["AlexNet"],"macs":[512],"sram":["unlimited"],"#,
            r#""strategies":["optimal"],"modes":["active"]}"#
        ),
        "fusion" => r#"{"cmd":"fusion","networks":["AlexNet"],"depth":2,"macs":512}"#,
        "analyze" => r#"{"cmd":"analyze","network":"AlexNet","macs":512}"#,
        "tables" => r#"{"cmd":"tables","table":"table3"}"#,
        "zoo" => r#"{"cmd":"zoo"}"#,
        "metrics" => r#"{"cmd":"metrics"}"#,
        "version" => r#"{"cmd":"version"}"#,
        _ => return None,
    })
}

/// Expand a `--mix` string (`"sweep,explore,version"` or weighted
/// `"sweep:3,version:1"`) into the request-line rotation.
fn parse_mix(mix: &str) -> Result<Vec<&'static str>> {
    let mut lines = Vec::new();
    for token in mix.split(',') {
        let token = token.trim();
        let (name, count) = match token.split_once(':') {
            Some((name, count)) => {
                let count: usize = count
                    .parse()
                    .with_context(|| format!("bad weight in mix token '{token}'"))?;
                (name, count)
            }
            None => (token, 1),
        };
        if count == 0 || count > 1000 {
            bail!("mix weight for '{name}' must be 1..=1000, got {count}");
        }
        let Some(line) = canned(name) else {
            bail!(
                "unknown mix command '{name}' (known: sweep, explore, fusion, analyze, \
                 tables, zoo, metrics, version)"
            );
        };
        for _ in 0..count {
            lines.push(line);
        }
    }
    if lines.is_empty() {
        bail!("--mix expanded to no requests");
    }
    Ok(lines)
}

/// One client's keep-alive connection, re-established on demand.
struct BenchConn {
    port: u16,
    stream: Option<(TcpStream, BufReader<TcpStream>)>,
}

impl BenchConn {
    fn new(port: u16) -> BenchConn {
        BenchConn { port, stream: None }
    }

    fn roundtrip(&mut self, line: &str) -> std::io::Result<String> {
        if self.stream.is_none() {
            let stream = TcpStream::connect(("127.0.0.1", self.port))?;
            // A liveness guard only: server-side work is bounded by the
            // request-size cap, but a wedged server must not hang the
            // bench forever.
            stream.set_read_timeout(Some(Duration::from_secs(30)))?;
            let reader = BufReader::new(stream.try_clone()?);
            self.stream = Some((stream, reader));
        }
        let (writer, reader) = self.stream.as_mut().expect("connected above");
        let result = exchange(writer, reader, line);
        if result.is_err() {
            self.stream = None;
        }
        result
    }

    /// Drop the connection (after a shed reply: the server closes it).
    fn disconnect(&mut self) {
        self.stream = None;
    }
}

/// One request/reply exchange on an established connection.
fn exchange(
    writer: &mut TcpStream,
    reader: &mut BufReader<TcpStream>,
    line: &str,
) -> std::io::Result<String> {
    writeln!(writer, "{line}")?;
    let mut reply = String::new();
    if reader.read_line(&mut reply)? == 0 {
        return Err(std::io::Error::new(
            std::io::ErrorKind::UnexpectedEof,
            "server closed the connection",
        ));
    }
    Ok(reply)
}

#[derive(Default)]
struct ClientStats {
    served: u64,
    shed: u64,
    errors: u64,
    attempted: usize,
    latencies_us: Vec<u64>,
}

fn run_client(
    port: u16,
    mix: &[&'static str],
    client: usize,
    share: usize,
    deadline: Option<Instant>,
) -> ClientStats {
    let mut conn = BenchConn::new(port);
    let mut stats = ClientStats::default();
    let mut consecutive_failures = 0u32;
    let mut i = 0usize;
    loop {
        let done = match deadline {
            Some(d) => Instant::now() >= d,
            None => i >= share,
        };
        if done {
            break;
        }
        let line = mix[(client + i) % mix.len()];
        stats.attempted += 1;
        let t0 = Instant::now();
        match conn.roundtrip(line) {
            Ok(reply) => {
                stats.latencies_us.push(t0.elapsed().as_micros() as u64);
                consecutive_failures = 0;
                match Json::parse(reply.trim()) {
                    Ok(json) if json.get("code").and_then(Json::as_str) == Some("too_busy") => {
                        stats.shed += 1;
                        conn.disconnect();
                    }
                    Ok(json) if json.get("error").is_some() => stats.errors += 1,
                    Ok(_) => stats.served += 1,
                    Err(_) => stats.errors += 1,
                }
            }
            Err(_) => {
                stats.errors += 1;
                consecutive_failures += 1;
                if consecutive_failures > 3 {
                    // The server is gone; stop burning the share.
                    break;
                }
            }
        }
        i += 1;
    }
    stats
}

/// `psim bench [--port P] [--clients C] [--requests N] [--duration SECS]
/// [--mix sweep,explore,version] [--out FILE] [--stats]`
///
/// Fires `--requests` total requests (split exactly across `--clients`
/// connections, like `psim infer`), or runs for `--duration` seconds
/// when given. Prints the JSON summary to stdout (and `--out FILE`), a
/// human line to stderr. `--stats` additionally polls the server's live
/// `{"cmd":"stats"}` snapshot after the run and reports the queue-wait
/// vs compute split to stderr. Exit code 1 when any request errored —
/// `too_busy` sheds are expected under saturation and do NOT fail the
/// run.
pub fn bench(args: &Args) -> Result<i32> {
    let port = args.opt_usize("port")?.unwrap_or(7878) as u16;
    let clients = args.opt_usize("clients")?.unwrap_or(4).clamp(1, 256);
    let requests = args.opt_usize("requests")?.unwrap_or(256);
    let duration_s = args.opt_usize("duration")?;
    let mix_str = args.opt("mix").unwrap_or("sweep,explore,version").to_string();
    let out = args.opt("out").map(String::from);
    let poll_stats = args.flag("stats");
    args.reject_unknown()?;
    let mix = parse_mix(&mix_str)?;

    // Probe before spawning clients: fail fast (and clearly) when no
    // server is listening.
    let mut probe = BenchConn::new(port);
    probe
        .roundtrip(r#"{"cmd":"version"}"#)
        .with_context(|| format!("connecting to 127.0.0.1:{port} — is `psim serve` running?"))?;
    drop(probe);

    let t0 = Instant::now();
    let deadline = duration_s.map(|s| t0 + Duration::from_secs(s as u64));
    let shares = split_shares(requests, clients);
    let per_client: Vec<ClientStats> = std::thread::scope(|scope| {
        let handles: Vec<_> = shares
            .iter()
            .enumerate()
            .map(|(c, &share)| {
                let mix = &mix;
                scope.spawn(move || run_client(port, mix, c, share, deadline))
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("client thread")).collect()
    });
    let wall = t0.elapsed();

    let mut run = BenchRun {
        clients,
        mix: mix_str,
        requests: 0,
        served: 0,
        shed: 0,
        errors: 0,
        wall,
        latencies_us: Vec::new(),
    };
    for stats in per_client {
        run.requests += stats.attempted;
        run.served += stats.served;
        run.shed += stats.shed;
        run.errors += stats.errors;
        run.latencies_us.extend(stats.latencies_us);
    }

    let summary = run.summary();
    println!("{summary}");
    eprintln!("{}", run.human_line());
    if poll_stats {
        let snap = super::stats::fetch(port).context("polling server stats after the run")?;
        eprintln!("{}", super::stats::human_line(&snap));
    }
    if let Some(path) = out {
        std::fs::write(&path, format!("{summary}\n"))
            .with_context(|| format!("writing {path}"))?;
    }
    Ok(if run.errors == 0 { 0 } else { 1 })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mix_expands_tokens_and_weights() {
        let mix = parse_mix("sweep,version").unwrap();
        assert_eq!(mix.len(), 2);
        assert!(mix[0].contains("\"sweep\""));
        assert!(mix[1].contains("\"version\""));
        let weighted = parse_mix("version:3,metrics").unwrap();
        assert_eq!(weighted.len(), 4);
        assert_eq!(weighted[0], weighted[2]);
    }

    #[test]
    fn mix_rejects_unknown_commands_and_bad_weights() {
        assert!(parse_mix("frobnicate").is_err());
        assert!(parse_mix("sweep:0").is_err());
        assert!(parse_mix("sweep:9999").is_err());
        assert!(parse_mix("sweep:abc").is_err());
        assert!(parse_mix("").is_err());
    }

    #[test]
    fn every_canned_line_is_a_valid_request() {
        for cmd in ["sweep", "explore", "fusion", "analyze", "tables", "zoo", "metrics", "version"]
        {
            let line = canned(cmd).unwrap();
            let req = crate::api::codec::decode_line(line)
                .unwrap_or_else(|e| panic!("canned {cmd} line rejected: {e}"));
            assert_eq!(req.cmd(), cmd, "canned line dispatches as its own command");
        }
        assert!(canned("shutdown").is_none(), "bench must never shut the server down");
    }
}
