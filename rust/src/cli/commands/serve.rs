//! `serve` / `client`: a TCP JSON-lines inference server + load generator.
//!
//! Protocol (one JSON object per line):
//!   request : {"image": [3072 floats]}            -> inference
//!             {"cmd": "metrics"}                  -> server metrics
//!             {"cmd": "shutdown"}                 -> stop the server
//!   response: {"id": n, "class": c, "logits": [...], "latency_us": n}
//!             {"metrics": "..."} / {"ok": true} / {"error": "..."}

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::cli::args::Args;
use crate::coordinator::{InferenceService, ServiceConfig};
use crate::runtime::{ArtifactDir, Tensor};
use crate::util::json::Json;

const IMAGE_ELEMS: usize = 3 * 32 * 32;

/// `psim serve [--port P] [--max-batch B]`
pub fn serve(args: &Args) -> Result<i32> {
    let port = args.opt_usize("port")?.unwrap_or(7878) as u16;
    let max_batch = args.opt_usize("max-batch")?.unwrap_or(8).clamp(1, 8);
    args.reject_unknown()?;

    let service = Arc::new(InferenceService::start(
        ArtifactDir::open_default()?,
        ServiceConfig { max_batch, ..ServiceConfig::default() },
    )?);
    let listener =
        TcpListener::bind(("127.0.0.1", port)).with_context(|| format!("binding port {port}"))?;
    println!("psim serve: listening on 127.0.0.1:{port} (max_batch={max_batch})");
    let shutdown = Arc::new(AtomicBool::new(false));

    std::thread::scope(|scope| -> Result<()> {
        for stream in listener.incoming() {
            if shutdown.load(Ordering::SeqCst) {
                break;
            }
            let stream = stream?;
            let service = service.clone();
            let shutdown = shutdown.clone();
            scope.spawn(move || {
                if let Err(e) = handle_conn(stream, &service, &shutdown) {
                    eprintln!("psim serve: connection error: {e:#}");
                }
            });
        }
        Ok(())
    })?;
    println!("psim serve: shut down. {}", service.metrics.summary());
    Ok(0)
}

fn handle_conn(
    stream: TcpStream,
    service: &InferenceService,
    shutdown: &AtomicBool,
) -> Result<()> {
    let peer = stream.peer_addr()?;
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let reply = match handle_line(&line, service, shutdown) {
            Ok(j) => j,
            Err(e) => Json::obj(vec![("error", Json::Str(format!("{e:#}")))]),
        };
        writeln!(writer, "{reply}")?;
        if shutdown.load(Ordering::SeqCst) {
            // Poke the accept loop so it observes the flag.
            let _ = TcpStream::connect(writer.local_addr()?);
            break;
        }
    }
    let _ = peer;
    Ok(())
}

fn handle_line(line: &str, service: &InferenceService, shutdown: &AtomicBool) -> Result<Json> {
    let msg = Json::parse(line).map_err(|e| anyhow::anyhow!("bad json: {e}"))?;
    if let Some(cmd) = msg.get("cmd").and_then(|c| c.as_str()) {
        return match cmd {
            "metrics" => Ok(Json::obj(vec![("metrics", Json::Str(service.metrics.summary()))])),
            "shutdown" => {
                shutdown.store(true, Ordering::SeqCst);
                Ok(Json::obj(vec![("ok", Json::Bool(true))]))
            }
            other => Err(anyhow::anyhow!("unknown cmd '{other}'")),
        };
    }
    let image = msg
        .get("image")
        .and_then(|i| i.as_arr())
        .ok_or_else(|| anyhow::anyhow!("missing 'image' array"))?;
    anyhow::ensure!(
        image.len() == IMAGE_ELEMS,
        "image must have {IMAGE_ELEMS} floats, got {}",
        image.len()
    );
    let data: Vec<f32> =
        image.iter().map(|v| v.as_f64().unwrap_or(0.0) as f32).collect();
    let tensor = Tensor::new(vec![3, 32, 32], data)?;
    let resp = service.infer(tensor)?;
    Ok(Json::obj(vec![
        ("id", Json::Num(resp.id as f64)),
        ("class", Json::Num(resp.top_class() as f64)),
        ("logits", Json::Arr(resp.logits.iter().map(|&v| Json::Num(v as f64)).collect())),
        ("latency_us", Json::Num(resp.latency_us as f64)),
    ]))
}

/// `psim client [--port P] [--requests N]` — fire N random images at a
/// running server and report client-observed latency/throughput.
pub fn client(args: &Args) -> Result<i32> {
    let port = args.opt_usize("port")?.unwrap_or(7878) as u16;
    let requests = args.opt_usize("requests")?.unwrap_or(16);
    args.reject_unknown()?;

    let stream = TcpStream::connect(("127.0.0.1", port))
        .with_context(|| format!("connecting to 127.0.0.1:{port} — is `psim serve` running?"))?;
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);

    let t0 = std::time::Instant::now();
    let mut line = String::new();
    for i in 0..requests {
        let img = Tensor::random(&[3, 32, 32], i as u64, 1.0);
        let payload = Json::obj(vec![(
            "image",
            Json::Arr(img.data.iter().map(|&v| Json::Num(v as f64)).collect()),
        )]);
        writeln!(writer, "{payload}")?;
        line.clear();
        reader.read_line(&mut line)?;
        let resp = Json::parse(&line).map_err(|e| anyhow::anyhow!("bad response: {e}"))?;
        if let Some(err) = resp.get("error") {
            anyhow::bail!("server error: {err}");
        }
    }
    let wall = t0.elapsed();
    println!(
        "client: {requests} requests in {:.3}s ({:.1} img/s sequential)",
        wall.as_secs_f64(),
        requests as f64 / wall.as_secs_f64()
    );
    // fetch server-side metrics
    writeln!(writer, "{}", Json::obj(vec![("cmd", Json::Str("metrics".into()))]))?;
    line.clear();
    reader.read_line(&mut line)?;
    println!("server: {line}");
    Ok(0)
}
