//! `serve` / `client`: a TCP JSON-lines server + load generator.
//!
//! The server answers both functional inference and analytical
//! design-space queries on one connection, so a deployed instance can
//! serve traffic and explore accelerator configurations side by side.
//! When the PJRT artifacts are absent the server starts in
//! *analytics-only* mode: sweeps work, inference requests return an error.
//!
//! Protocol (one JSON object per line):
//!   request : {"image": [3072 floats]}            -> inference
//!             {"cmd": "sweep", ...}               -> design-space sweep
//!               optional keys: networks, macs, strategies, modes,
//!               batches, fusion_depth (see
//!               analytics::grid::SweepSpec::from_json), workers
//!             {"cmd": "explore", ...}             -> Pareto exploration
//!               optional keys: networks, macs, sram, strategies, modes,
//!               fusion, objectives (see
//!               dse::space::ExploreSpec::from_json), workers
//!             {"cmd": "metrics"}                  -> server metrics
//!             {"cmd": "shutdown"}                 -> stop the server
//!   response: {"id": n, "class": c, "logits": [...], "latency_us": n}
//!             {"cells": [...], "count": n, "cache_hits": h, ...}
//!             {"frontier": [...], "count": n, "evaluated": e, ...}
//!             {"metrics": "..."} / {"ok": true} / {"error": "..."}

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{Shutdown, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{Context, Result};

use crate::analytics::grid::{GridEngine, SweepSpec};
use crate::cli::args::Args;
use crate::coordinator::parallel::default_workers;
use crate::coordinator::{InferenceService, ServiceConfig};
use crate::dse::explore as dse_explore;
use crate::dse::space::ExploreSpec;
use crate::runtime::{ArtifactDir, Tensor};
use crate::util::json::Json;

const IMAGE_ELEMS: usize = 3 * 32 * 32;

/// Largest grid a single sweep request may expand to.
const MAX_SWEEP_CELLS: usize = 100_000;

/// Shared server state: the (optional) inference stack plus the sweep
/// engine, whose layer-shape cache warms up across requests.
pub struct ServerState {
    service: Option<InferenceService>,
    /// Why inference is unavailable (the real artifact-load error), so
    /// per-request failures report the actual cause, not a guess.
    inference_error: Option<String>,
    grid: GridEngine,
}

/// Live connection sockets, so `{"cmd":"shutdown"}` can unblock peers
/// parked in a blocking read. Without this, `thread::scope` in
/// [`serve_on`] waits forever on idle keep-alive clients (their handler
/// threads sit in `reader.lines()` until the *client* hangs up).
#[derive(Default)]
struct ConnRegistry {
    conns: Mutex<HashMap<u64, TcpStream>>,
    next_id: AtomicU64,
}

impl ConnRegistry {
    /// Track a connection; returns the handle to deregister with.
    /// `None` (a failed `try_clone`) means the connection CANNOT be
    /// tracked — the caller must refuse to serve it, because an untracked
    /// idle reader would be unreachable by [`ConnRegistry::shutdown_all`]
    /// and reintroduce the shutdown hang.
    fn register(&self, stream: &TcpStream) -> Option<u64> {
        let clone = stream.try_clone().ok()?;
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        self.conns.lock().unwrap().insert(id, clone);
        Some(id)
    }

    fn deregister(&self, id: u64) {
        self.conns.lock().unwrap().remove(&id);
    }

    /// Shut down every tracked socket: blocked readers see EOF/error and
    /// their handler threads exit. Sockets stay registered until their
    /// handler deregisters; double-shutdown is harmless.
    fn shutdown_all(&self) {
        for conn in self.conns.lock().unwrap().values() {
            let _ = conn.shutdown(Shutdown::Both);
        }
    }
}

impl ServerState {
    /// Build the state, degrading to analytics-only when the artifact
    /// directory is unavailable.
    fn start(max_batch: usize) -> Result<ServerState> {
        let (service, inference_error) = match ArtifactDir::open_default() {
            Ok(artifacts) => (
                Some(InferenceService::start(
                    artifacts,
                    ServiceConfig { max_batch, ..ServiceConfig::default() },
                )?),
                None,
            ),
            Err(e) => {
                eprintln!(
                    "psim serve: inference disabled ({e:#}); \
                     serving design-space queries only"
                );
                (None, Some(format!("{e:#}")))
            }
        };
        Ok(ServerState { service, inference_error, grid: GridEngine::new() })
    }
}

/// `psim serve [--port P] [--max-batch B]`
pub fn serve(args: &Args) -> Result<i32> {
    let port = args.opt_usize("port")?.unwrap_or(7878) as u16;
    let max_batch = args.opt_usize("max-batch")?.unwrap_or(8).clamp(1, 8);
    args.reject_unknown()?;

    let state = Arc::new(ServerState::start(max_batch)?);
    let listener =
        TcpListener::bind(("127.0.0.1", port)).with_context(|| format!("binding port {port}"))?;
    println!(
        "psim serve: listening on 127.0.0.1:{port} (max_batch={max_batch}, inference {})",
        if state.service.is_some() { "enabled" } else { "disabled" }
    );
    serve_on(listener, &state)?;
    let (hits, misses) = state.grid.cache_stats();
    match &state.service {
        Some(service) => println!("psim serve: shut down. {}", service.metrics.summary()),
        None => println!("psim serve: shut down. sweep cache {hits} hits / {misses} misses"),
    }
    Ok(0)
}

/// Accept loop: runs until a `{"cmd":"shutdown"}` request flips the flag.
/// Guaranteed to return even with idle keep-alive clients connected: the
/// shutting-down handler closes every registered socket, so no handler
/// thread can stay parked in a blocking read (regression-tested by
/// `shutdown_unblocks_idle_connections`).
fn serve_on(listener: TcpListener, state: &Arc<ServerState>) -> Result<()> {
    let shutdown = Arc::new(AtomicBool::new(false));
    let registry = Arc::new(ConnRegistry::default());

    std::thread::scope(|scope| -> Result<()> {
        for stream in listener.incoming() {
            if shutdown.load(Ordering::SeqCst) {
                break;
            }
            let stream = stream?;
            let state = state.clone();
            let shutdown = shutdown.clone();
            let registry = registry.clone();
            scope.spawn(move || {
                if let Err(e) = handle_conn(stream, &state, &shutdown, &registry) {
                    eprintln!("psim serve: connection error: {e:#}");
                }
            });
        }
        Ok(())
    })
}

fn handle_conn(
    stream: TcpStream,
    state: &ServerState,
    shutdown: &AtomicBool,
    registry: &ConnRegistry,
) -> Result<()> {
    let Some(id) = registry.register(&stream) else {
        // Untrackable (try_clone failed, e.g. fd exhaustion): refuse the
        // connection rather than serve a socket shutdown can't reach.
        return Ok(());
    };
    // A connection accepted in the shutdown race window is never served:
    // the flag is set before `shutdown_all`, so either our socket was
    // already shut or we observe the flag here.
    let result = if shutdown.load(Ordering::SeqCst) {
        Ok(())
    } else {
        conn_loop(stream, state, shutdown, registry)
    };
    registry.deregister(id);
    result
}

/// One connection's request/reply loop.
fn conn_loop(
    stream: TcpStream,
    state: &ServerState,
    shutdown: &AtomicBool,
    registry: &ConnRegistry,
) -> Result<()> {
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = match line {
            Ok(line) => line,
            // A peer unblocked by shutdown_all surfaces a read error
            // (or EOF, which ends the iterator) — not a failure.
            Err(_) if shutdown.load(Ordering::SeqCst) => break,
            Err(e) => return Err(e.into()),
        };
        if line.trim().is_empty() {
            continue;
        }
        let reply = match handle_line(&line, state, shutdown) {
            Ok(j) => j,
            Err(e) => Json::obj(vec![("error", Json::Str(format!("{e:#}")))]),
        };
        if let Err(e) = writeln!(writer, "{reply}") {
            // A write aborted by shutdown_all (broken pipe) is part of a
            // clean shutdown, not a connection error.
            if shutdown.load(Ordering::SeqCst) {
                break;
            }
            return Err(e.into());
        }
        if shutdown.load(Ordering::SeqCst) {
            // Poke the accept loop so it observes the flag, then unblock
            // every other connection's parked reader.
            let _ = TcpStream::connect(writer.local_addr()?);
            registry.shutdown_all();
            break;
        }
    }
    Ok(())
}

/// Dispatch one request line. Public within the crate for direct testing
/// without a TCP round-trip.
fn handle_line(line: &str, state: &ServerState, shutdown: &AtomicBool) -> Result<Json> {
    let msg = Json::parse(line).map_err(|e| anyhow::anyhow!("bad json: {e}"))?;
    if let Some(cmd) = msg.get("cmd").and_then(|c| c.as_str()) {
        return match cmd {
            "metrics" => {
                let summary = match &state.service {
                    Some(service) => service.metrics.summary(),
                    None => "inference disabled (analytics-only mode)".to_string(),
                };
                Ok(Json::obj(vec![("metrics", Json::Str(summary))]))
            }
            "sweep" => handle_sweep(&msg, state),
            "explore" => handle_explore(&msg, state),
            "shutdown" => {
                shutdown.store(true, Ordering::SeqCst);
                Ok(Json::obj(vec![("ok", Json::Bool(true))]))
            }
            other => Err(anyhow::anyhow!("unknown cmd '{other}'")),
        };
    }
    let image = msg
        .get("image")
        .and_then(|i| i.as_arr())
        .ok_or_else(|| anyhow::anyhow!("missing 'image' array"))?;
    let service = state.service.as_ref().ok_or_else(|| {
        anyhow::anyhow!(
            "inference unavailable: {}",
            state.inference_error.as_deref().unwrap_or("service not started")
        )
    })?;
    anyhow::ensure!(
        image.len() == IMAGE_ELEMS,
        "image must have {IMAGE_ELEMS} floats, got {}",
        image.len()
    );
    let data: Vec<f32> = image.iter().map(|v| v.as_f64().unwrap_or(0.0) as f32).collect();
    let tensor = Tensor::new(vec![3, 32, 32], data)?;
    let resp = service.infer(tensor)?;
    Ok(Json::obj(vec![
        ("id", Json::Num(resp.id as f64)),
        ("class", Json::Num(resp.top_class() as f64)),
        ("logits", Json::Arr(resp.logits.iter().map(|&v| Json::Num(v as f64)).collect())),
        ("latency_us", Json::Num(resp.latency_us as f64)),
    ]))
}

/// Parse a request's optional `workers` field (default: machine
/// parallelism), clamped to the server's per-request cap. Shared by the
/// `sweep` and `explore` handlers so the policy cannot drift.
fn request_workers(msg: &Json) -> Result<usize> {
    Ok(msg
        .get("workers")
        .map(|w| {
            w.as_usize().ok_or_else(|| anyhow::anyhow!("'workers' must be a positive integer"))
        })
        .transpose()?
        .unwrap_or_else(default_workers)
        .clamp(1, 64))
}

/// `{"cmd":"sweep", ...}` — run a design-space grid and return its cells.
///
/// `cache_hits`/`cache_misses` are the deltas observed around this
/// request's run (approximate if sweeps run concurrently, since the
/// layer cache is shared — that sharing is the point).
fn handle_sweep(msg: &Json, state: &ServerState) -> Result<Json> {
    let spec = SweepSpec::from_json(msg)?;
    anyhow::ensure!(
        spec.cell_count() <= MAX_SWEEP_CELLS,
        "sweep expands to {} cells (limit {MAX_SWEEP_CELLS})",
        spec.cell_count()
    );
    let workers = request_workers(msg)?;
    let (hits_before, misses_before) = state.grid.cache_stats();
    let grid = state.grid.run_with_workers(&spec, workers);
    let (hits_after, misses_after) = state.grid.cache_stats();
    Ok(Json::obj(vec![
        ("cells", Json::Arr(grid.cells.iter().map(|c| c.to_json()).collect())),
        ("count", Json::Num(grid.len() as f64)),
        ("cache_hits", Json::Num(hits_after.saturating_sub(hits_before) as f64)),
        ("cache_misses", Json::Num(misses_after.saturating_sub(misses_before) as f64)),
    ]))
}

/// `{"cmd":"explore", ...}` — run the design-space explorer and return
/// the Pareto frontier. The long-lived grid engine serves the partition/
/// bandwidth memo cache, so repeated explorations get warmer.
fn handle_explore(msg: &Json, state: &ServerState) -> Result<Json> {
    let spec = ExploreSpec::from_json(msg)?;
    anyhow::ensure!(
        spec.candidate_count() <= MAX_SWEEP_CELLS,
        "explore expands to {} candidates (limit {MAX_SWEEP_CELLS})",
        spec.candidate_count()
    );
    let workers = request_workers(msg)?;
    let result = dse_explore::explore(&state.grid, &spec, workers);
    Ok(Json::obj(vec![
        ("frontier", Json::Arr(result.frontier.iter().map(|f| f.to_json()).collect())),
        ("count", Json::Num(result.frontier.len() as f64)),
        ("candidates", Json::Num(result.candidates as f64)),
        ("evaluated", Json::Num(result.evaluated as f64)),
        ("pruned", Json::Num(result.pruned.len() as f64)),
        ("infeasible", Json::Num(result.infeasible as f64)),
    ]))
}

/// `psim client [--port P] [--requests N]` — fire N random images at a
/// running server and report client-observed latency/throughput.
pub fn client(args: &Args) -> Result<i32> {
    let port = args.opt_usize("port")?.unwrap_or(7878) as u16;
    let requests = args.opt_usize("requests")?.unwrap_or(16);
    args.reject_unknown()?;

    let stream = TcpStream::connect(("127.0.0.1", port))
        .with_context(|| format!("connecting to 127.0.0.1:{port} — is `psim serve` running?"))?;
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);

    let t0 = std::time::Instant::now();
    let mut line = String::new();
    for i in 0..requests {
        let img = Tensor::random(&[3, 32, 32], i as u64, 1.0);
        let payload = Json::obj(vec![(
            "image",
            Json::Arr(img.data.iter().map(|&v| Json::Num(v as f64)).collect()),
        )]);
        writeln!(writer, "{payload}")?;
        line.clear();
        reader.read_line(&mut line)?;
        let resp = Json::parse(&line).map_err(|e| anyhow::anyhow!("bad response: {e}"))?;
        if let Some(err) = resp.get("error") {
            anyhow::bail!("server error: {err}");
        }
    }
    let wall = t0.elapsed();
    println!(
        "client: {requests} requests in {:.3}s ({:.1} img/s sequential)",
        wall.as_secs_f64(),
        requests as f64 / wall.as_secs_f64()
    );
    // fetch server-side metrics
    writeln!(writer, "{}", Json::obj(vec![("cmd", Json::Str("metrics".into()))]))?;
    line.clear();
    reader.read_line(&mut line)?;
    println!("server: {line}");
    Ok(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Analytics-only state (no artifacts needed) for protocol tests.
    fn analytics_state() -> ServerState {
        ServerState {
            service: None,
            inference_error: Some("no artifacts (test fixture)".to_string()),
            grid: GridEngine::new(),
        }
    }

    #[test]
    fn sweep_request_returns_cells() {
        let state = analytics_state();
        let shutdown = AtomicBool::new(false);
        let reply = handle_line(
            r#"{"cmd":"sweep","networks":["AlexNet"],"macs":[512,2048],
               "strategies":["optimal"],"modes":["passive","active"],"workers":2}"#,
            &state,
            &shutdown,
        )
        .unwrap();
        assert_eq!(reply.get("count").unwrap().as_usize(), Some(4));
        let cells = reply.get("cells").unwrap().as_arr().unwrap();
        assert_eq!(cells.len(), 4);
        assert_eq!(cells[0].get("network").unwrap().as_str(), Some("AlexNet"));
        assert!(cells[0].get("total").unwrap().as_f64().unwrap() > 0.0);
        assert!(!shutdown.load(Ordering::SeqCst));
    }

    #[test]
    fn sweep_request_accepts_fusion_depth() {
        let state = analytics_state();
        let shutdown = AtomicBool::new(false);
        let reply = handle_line(
            r#"{"cmd":"sweep","networks":["AlexNet"],"macs":[512],
               "strategies":["optimal"],"modes":["passive"],"fusion_depth":[1,2]}"#,
            &state,
            &shutdown,
        )
        .unwrap();
        let cells = reply.get("cells").unwrap().as_arr().unwrap();
        assert_eq!(cells.len(), 2);
        assert!(cells[0].get("fusion_depth").is_none());
        assert_eq!(cells[1].get("fusion_depth").unwrap().as_usize(), Some(2));
        let fused = cells[1].get("total").unwrap().as_f64().unwrap();
        let unfused = cells[0].get("total").unwrap().as_f64().unwrap();
        assert!(fused < unfused);
        assert!(handle_line(r#"{"cmd":"sweep","fusion_depth":0}"#, &state, &shutdown).is_err());
    }

    #[test]
    fn shutdown_unblocks_idle_connections() {
        use std::time::Duration;

        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let state = Arc::new(analytics_state());
        let (tx, rx) = std::sync::mpsc::channel();
        let server = std::thread::spawn(move || {
            let result = serve_on(listener, &state);
            let _ = tx.send(());
            result
        });

        // An idle keep-alive client: connects, sends nothing, stays open.
        // Pre-fix, its handler thread blocked in `reader.lines()` forever
        // and `thread::scope` never returned.
        let idle = TcpStream::connect(addr).unwrap();
        std::thread::sleep(Duration::from_millis(50)); // let it park in read

        let ctl = TcpStream::connect(addr).unwrap();
        let mut writer = ctl.try_clone().unwrap();
        let mut reader = BufReader::new(ctl);
        let mut line = String::new();
        writeln!(writer, r#"{{"cmd":"metrics"}}"#).unwrap();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("metrics"), "{line}");
        line.clear();
        writeln!(writer, r#"{{"cmd":"shutdown"}}"#).unwrap();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("true"), "{line}");

        rx.recv_timeout(Duration::from_secs(10))
            .expect("server did not shut down while an idle connection was open");
        server.join().unwrap().unwrap();
        drop(idle);
    }

    #[test]
    fn sweep_cache_warms_across_requests() {
        let state = analytics_state();
        let shutdown = AtomicBool::new(false);
        let req = r#"{"cmd":"sweep","networks":["resnet18"],"macs":[1024],
                      "strategies":["optimal"],"modes":["passive"]}"#;
        let first = handle_line(req, &state, &shutdown).unwrap();
        let second = handle_line(req, &state, &shutdown).unwrap();
        // Per-request deltas: the first sweep populates the cache, the
        // second identical one computes nothing new.
        assert!(first.get("cache_misses").unwrap().as_f64().unwrap() > 0.0);
        assert_eq!(second.get("cache_misses").unwrap().as_f64().unwrap(), 0.0);
        assert!(second.get("cache_hits").unwrap().as_f64().unwrap() > 0.0);
    }

    #[test]
    fn explore_request_returns_frontier() {
        let state = analytics_state();
        let shutdown = AtomicBool::new(false);
        let reply = handle_line(
            r#"{"cmd":"explore","networks":["AlexNet"],"macs":[512,1024],
               "sram":["unlimited","64k"],"strategies":["optimal"],
               "modes":["passive","active"],"workers":2}"#,
            &state,
            &shutdown,
        )
        .unwrap();
        let frontier = reply.get("frontier").unwrap().as_arr().unwrap();
        assert!(!frontier.is_empty());
        assert_eq!(reply.get("count").unwrap().as_usize(), Some(frontier.len()));
        assert_eq!(reply.get("candidates").unwrap().as_usize(), Some(8));
        let evaluated = reply.get("evaluated").unwrap().as_usize().unwrap();
        let pruned = reply.get("pruned").unwrap().as_usize().unwrap();
        assert_eq!(evaluated + pruned, 8);
        assert_eq!(frontier[0].get("network").unwrap().as_str(), Some("AlexNet"));
        assert!(frontier[0].get("bandwidth").unwrap().as_f64().unwrap() > 0.0);
        // the same engine cache serves sweeps and explorations
        assert!(state.grid.cache_stats().1 > 0);
    }

    #[test]
    fn explore_request_validation() {
        let state = analytics_state();
        let shutdown = AtomicBool::new(false);
        for bad in [
            r#"{"cmd":"explore","networks":["Nope"]}"#,
            r#"{"cmd":"explore","sram":[0]}"#,
            r#"{"cmd":"explore","objectives":["latency"]}"#,
            r#"{"cmd":"explore","strategy":["optimal"]}"#,
        ] {
            assert!(handle_line(bad, &state, &shutdown).is_err(), "accepted {bad}");
        }
    }

    #[test]
    fn sweep_request_validation() {
        let state = analytics_state();
        let shutdown = AtomicBool::new(false);
        assert!(handle_line(r#"{"cmd":"sweep","networks":["Nope"]}"#, &state, &shutdown).is_err());
        assert!(handle_line(r#"{"cmd":"sweep","macs":[0]}"#, &state, &shutdown).is_err());
        assert!(handle_line(r#"{"cmd":"bogus"}"#, &state, &shutdown).is_err());
        assert!(handle_line("not json", &state, &shutdown).is_err());
    }

    #[test]
    fn inference_without_artifacts_is_a_clean_error() {
        let state = analytics_state();
        let shutdown = AtomicBool::new(false);
        let img = format!(
            r#"{{"image":[{}]}}"#,
            std::iter::repeat("0").take(IMAGE_ELEMS).collect::<Vec<_>>().join(",")
        );
        let err = handle_line(&img, &state, &shutdown).unwrap_err().to_string();
        assert!(err.contains("inference unavailable"), "{err}");
    }

    #[test]
    fn metrics_and_shutdown_work_without_service() {
        let state = analytics_state();
        let shutdown = AtomicBool::new(false);
        let m = handle_line(r#"{"cmd":"metrics"}"#, &state, &shutdown).unwrap();
        assert!(m.get("metrics").unwrap().as_str().unwrap().contains("disabled"));
        let s = handle_line(r#"{"cmd":"shutdown"}"#, &state, &shutdown).unwrap();
        assert_eq!(s.get("ok"), Some(&Json::Bool(true)));
        assert!(shutdown.load(Ordering::SeqCst));
    }
}
