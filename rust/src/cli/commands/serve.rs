//! `serve` / `client`: a TCP JSON-lines server + load generator.
//!
//! The server is a thin socket layer over [`crate::api::Engine`]: every
//! line is decoded, dispatched and encoded by the typed facade
//! ([`Engine::handle_line_shared`]), so the wire protocol, the
//! request-size caps and the per-request metrics are exactly the ones
//! every other frontend (CLI commands, `psim request`, library
//! embedders) gets. When the PJRT artifacts are absent the server starts
//! in *analytics-only* mode: analytics commands work, inference requests
//! report `inference_unavailable`.
//!
//! Concurrency model (PR 6): a **bounded worker pool**, not a thread per
//! connection. The accept loop admits at most `--max-conns` live
//! connections and hands them to `--workers` threads through a bounded
//! [`Bounded`] queue of `--queue` slots. When the queue is full (or the
//! connection limit is reached) the connection is **shed** immediately
//! with one stable `{"code":"too_busy",...}` line instead of queueing
//! unboundedly — the paper's finite-resource discipline applied to the
//! server itself. `--timeout-ms` bounds how long a worker waits on (or
//! writes to) a kept-alive connection, so idle peers cannot pin workers.
//! Identical in-flight analytics requests are coalesced by the engine
//! (one computation, fan-out replies), and `--store DIR` attaches the
//! content-addressed result store ([`crate::store`]) so repeated
//! analytics requests replay memoized reply bytes across time and
//! process restarts.
//!
//! Protocol (one JSON object per line): see the README's protocol table
//! (generated from [`crate::api::COMMANDS`]) or [`crate::api::codec`].
//! Errors reply `{"code": "...", "error": "..."}` with a stable
//! machine-readable code.

use std::collections::HashMap;
use std::io::{BufRead, BufReader, ErrorKind, Write};
use std::net::{Shutdown, TcpListener, TcpStream};
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{Context, Result};

use crate::api::{ApiError, Engine, ServeStats};
use crate::cli::args::Args;
use crate::coordinator::pool::Bounded;
use crate::obs::span;
use crate::runtime::Tensor;
use crate::store::{ResultStore, DEFAULT_CAPACITY as DEFAULT_STORE_CAPACITY};
use crate::util::json::Json;
use crate::util::sync::lock_unpoisoned;

/// Live connection sockets, so `{"cmd":"shutdown"}` can unblock peers
/// parked in a blocking read. Without this, `thread::scope` in
/// [`serve_on`] waits forever on idle keep-alive clients (their worker
/// threads sit in a blocking read until the *client* hangs up).
#[derive(Default)]
struct ConnRegistry {
    conns: Mutex<HashMap<u64, TcpStream>>,
    next_id: AtomicU64,
}

impl ConnRegistry {
    /// Track a connection; returns the handle to deregister with.
    /// `None` (a failed `try_clone`) means the connection CANNOT be
    /// tracked — the caller must refuse to serve it, because an untracked
    /// idle reader would be unreachable by [`ConnRegistry::shutdown_all`]
    /// and reintroduce the shutdown hang.
    fn register(&self, stream: &TcpStream) -> Option<u64> {
        let clone = stream.try_clone().ok()?;
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        lock_unpoisoned(&self.conns).insert(id, clone);
        Some(id)
    }

    fn deregister(&self, id: u64) {
        lock_unpoisoned(&self.conns).remove(&id);
    }

    /// Shut down every tracked socket: blocked readers see EOF/error and
    /// their worker threads move on. Sockets stay registered until their
    /// handler deregisters; double-shutdown is harmless.
    fn shutdown_all(&self) {
        for conn in lock_unpoisoned(&self.conns).values() {
            let _ = conn.shutdown(Shutdown::Both);
        }
    }
}

/// Pooled-server knobs, one field per `psim serve` flag. The defaults
/// are the flag defaults.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Worker threads serving connections (`--workers`, clamped 1..=64).
    pub workers: usize,
    /// Bounded hand-off queue capacity (`--queue`); 0 sheds every
    /// connection a worker cannot take immediately.
    pub queue: usize,
    /// Live-connection limit, queued + in service (`--max-conns`).
    pub max_conns: usize,
    /// Per-request read/write deadline (`--timeout-ms`; `None` = never).
    pub timeout: Option<Duration>,
}

impl Default for ServeConfig {
    fn default() -> Self {
        let timeout = Some(Duration::from_secs(30));
        ServeConfig { workers: 8, queue: 32, max_conns: 256, timeout }
    }
}

/// Bind `127.0.0.1:port`, returning the listener and the **actual** port
/// — with `--port 0` the OS picks an ephemeral one, which is what tests
/// and bench harnesses should use instead of racing on fixed ports.
pub fn bind(port: u16) -> Result<(TcpListener, u16)> {
    let listener =
        TcpListener::bind(("127.0.0.1", port)).with_context(|| format!("binding port {port}"))?;
    let actual = listener.local_addr().context("reading bound address")?.port();
    Ok((listener, actual))
}

/// `psim serve [--port P] [--max-batch B] [--workers N] [--queue N]
/// [--max-conns N] [--timeout-ms MS] [--store DIR]`
pub fn serve(args: &Args) -> Result<i32> {
    let port = args.opt_usize("port")?.unwrap_or(7878) as u16;
    let max_batch = args.opt_usize("max-batch")?.unwrap_or(8).clamp(1, 8);
    let config = ServeConfig {
        workers: args.opt_usize("workers")?.unwrap_or(8).clamp(1, 64),
        queue: args.opt_usize("queue")?.unwrap_or(32),
        max_conns: args.opt_usize("max-conns")?.unwrap_or(256).max(1),
        timeout: match args.opt_usize("timeout-ms")?.unwrap_or(30_000) {
            0 => None,
            ms => Some(Duration::from_millis(ms as u64)),
        },
    };
    let store_dir = args.opt("store").map(str::to_string);
    args.reject_unknown()?;

    let engine = Arc::new(Engine::start(max_batch)?);
    if let Some(err) = engine.inference_error() {
        eprintln!("psim serve: inference disabled ({err}); serving design-space queries only");
    }
    if let Some(dir) = &store_dir {
        let store =
            ResultStore::open(Path::new(dir), DEFAULT_STORE_CAPACITY, engine.registry())
                .with_context(|| format!("opening result store '{dir}'"))?;
        engine.attach_store(store);
        eprintln!(
            "psim serve: result store at {dir} (lru capacity {DEFAULT_STORE_CAPACITY} entries)"
        );
    }
    let (listener, port) = bind(port)?;
    println!(
        "psim serve: listening on 127.0.0.1:{port} (workers={}, queue={}, max_conns={}, \
         timeout_ms={}, max_batch={max_batch}, inference {})",
        config.workers,
        config.queue,
        config.max_conns,
        config.timeout.map_or(0, |t| t.as_millis()),
        if engine.has_inference() { "enabled" } else { "disabled" }
    );
    serve_on(listener, &engine, &config)?;
    let (hits, misses) = engine.cache_stats();
    match engine.service_metrics() {
        Some(summary) => println!("psim serve: shut down. {summary}"),
        None => println!("psim serve: shut down. sweep cache {hits} hits / {misses} misses"),
    }
    println!("psim serve: {}", engine.serve_stats().summary());
    Ok(0)
}

/// The pooled accept loop: runs until a `{"cmd":"shutdown"}` request
/// flips the flag. Public so integration tests (and embedders) can run
/// the real server on an ephemeral listener with test-sized pools.
///
/// Admission control happens here, in one place:
///
/// 1. untrackable sockets (`try_clone` failure) are refused and counted
///    ([`ServeStats::refused`]) — previously a silent drop;
/// 2. at `max_conns` live connections, or with the hand-off queue full,
///    the connection is shed with one `too_busy` line
///    ([`ServeStats::shed`]);
/// 3. otherwise it is queued for the worker pool
///    ([`ServeStats::accepted`]).
///
/// Guaranteed to return even with idle keep-alive clients connected: the
/// shutting-down worker closes every registered socket, so no worker can
/// stay parked in a blocking read (regression-tested by
/// `shutdown_unblocks_idle_connections` and `rust/tests/serve_stress.rs`).
pub fn serve_on(listener: TcpListener, engine: &Arc<Engine>, config: &ServeConfig) -> Result<()> {
    let shutdown = AtomicBool::new(false);
    let registry = ConnRegistry::default();
    let queue: Bounded<(TcpStream, u64)> = Bounded::new(config.queue);
    let live = AtomicUsize::new(0);
    let stats = engine.serve_stats();

    std::thread::scope(|scope| -> Result<()> {
        for _ in 0..config.workers.max(1) {
            scope.spawn(|| {
                while let Some(((stream, id), waited)) = queue.pop_timed() {
                    let waited_us = waited.as_micros() as u64;
                    stats.queue_wait.record(waited_us);
                    span::global().record_us(span::stage::QUEUE_WAIT, waited_us);
                    if let Err(e) = handle_conn(stream, engine, &shutdown, &registry) {
                        eprintln!("psim serve: connection error: {e:#}");
                    }
                    registry.deregister(id);
                    live.fetch_sub(1, Ordering::SeqCst);
                }
            });
        }

        let result = accept_loop(&listener, config, stats, &registry, &queue, &live, &shutdown);
        // Wake the pool: drain whatever is queued, then exit.
        queue.close();
        result
    })
}

/// Admission control, one connection per iteration (see [`serve_on`]).
fn accept_loop(
    listener: &TcpListener,
    config: &ServeConfig,
    stats: &ServeStats,
    registry: &ConnRegistry,
    queue: &Bounded<(TcpStream, u64)>,
    live: &AtomicUsize,
    shutdown: &AtomicBool,
) -> Result<()> {
    for stream in listener.incoming() {
        if shutdown.load(Ordering::SeqCst) {
            break;
        }
        let stream = stream?;
        // Deadlines are set before hand-off so queued time counts
        // against the connection's first request too.
        let _ = stream.set_read_timeout(config.timeout);
        let _ = stream.set_write_timeout(config.timeout);
        // Register before queueing: shutdown_all must reach sockets
        // still waiting in the queue.
        let Some(id) = registry.register(&stream) else {
            let refused = stats.refused.inc();
            eprintln!(
                "psim serve: refused untrackable connection \
                 (try_clone failed; {refused} refused so far)"
            );
            continue;
        };
        if live.load(Ordering::SeqCst) >= config.max_conns {
            shed(stream, id, registry, stats);
            continue;
        }
        match queue.try_push((stream, id)) {
            Ok(depth) => {
                live.fetch_add(1, Ordering::SeqCst);
                stats.accepted.inc();
                stats.note_queue_depth(depth);
            }
            Err((stream, id)) => shed(stream, id, registry, stats),
        }
    }
    Ok(())
}

/// Shed one connection: a single canonical `too_busy` line, then close.
/// Constant time and constant memory per connection — saturation can
/// never grow a backlog.
fn shed(mut stream: TcpStream, id: u64, registry: &ConnRegistry, stats: &ServeStats) {
    stats.shed.inc();
    let _ = writeln!(stream, "{}", ApiError::too_busy().to_json());
    let _ = stream.shutdown(Shutdown::Both);
    registry.deregister(id);
}

fn handle_conn(
    stream: TcpStream,
    engine: &Engine,
    shutdown: &AtomicBool,
    registry: &ConnRegistry,
) -> Result<()> {
    // A connection popped in the shutdown race window is never served:
    // the flag is set before `shutdown_all`, so either our socket was
    // already shut or we observe the flag here.
    if shutdown.load(Ordering::SeqCst) {
        return Ok(());
    }
    conn_loop(stream, engine, shutdown, registry)
}

/// One connection's request/reply loop: read a line, let the engine
/// decode + dispatch + encode it (coalescing identical in-flight
/// analytics requests), write the reply.
fn conn_loop(
    stream: TcpStream,
    engine: &Engine,
    shutdown: &AtomicBool,
    registry: &ConnRegistry,
) -> Result<()> {
    let stats = engine.serve_stats();
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = match line {
            Ok(line) => line,
            // A peer unblocked by shutdown_all surfaces a read error
            // (or EOF, which ends the iterator) — not a failure.
            Err(_) if shutdown.load(Ordering::SeqCst) => break,
            // The per-request deadline fired: reclaim the worker. A
            // clean close, counted but not an error.
            Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) => {
                stats.timed_out.inc();
                break;
            }
            Err(e) => return Err(e.into()),
        };
        if line.trim().is_empty() {
            continue;
        }
        let (reply, stop) = engine.handle_line_shared(&line);
        if stop {
            shutdown.store(true, Ordering::SeqCst);
        }
        let write_started = std::time::Instant::now();
        if let Err(e) = writeln!(writer, "{reply}") {
            // A write aborted by shutdown_all (broken pipe) is part of a
            // clean shutdown, not a connection error.
            if shutdown.load(Ordering::SeqCst) {
                break;
            }
            return Err(e.into());
        }
        span::global().record_us(span::stage::WRITE, write_started.elapsed().as_micros() as u64);
        stats.lines.inc();
        if shutdown.load(Ordering::SeqCst) {
            // Poke the accept loop so it observes the flag, then unblock
            // every other connection's parked reader.
            let _ = TcpStream::connect(writer.local_addr()?);
            registry.shutdown_all();
            break;
        }
    }
    Ok(())
}

/// `psim client [--port P] [--requests N]` — fire N random images at a
/// running server and report client-observed latency/throughput.
pub fn client(args: &Args) -> Result<i32> {
    let port = args.opt_usize("port")?.unwrap_or(7878) as u16;
    let requests = args.opt_usize("requests")?.unwrap_or(16);
    args.reject_unknown()?;

    let stream = TcpStream::connect(("127.0.0.1", port))
        .with_context(|| format!("connecting to 127.0.0.1:{port} — is `psim serve` running?"))?;
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);

    let t0 = std::time::Instant::now();
    let mut line = String::new();
    for i in 0..requests {
        let img = Tensor::random(&[3, 32, 32], i as u64, 1.0);
        let payload = Json::obj(vec![(
            "image",
            Json::Arr(img.data.iter().map(|&v| Json::Num(v as f64)).collect()),
        )]);
        writeln!(writer, "{payload}")?;
        line.clear();
        reader.read_line(&mut line)?;
        let resp = Json::parse(&line).map_err(|e| anyhow::anyhow!("bad response: {e}"))?;
        if let Some(err) = resp.get("error") {
            anyhow::bail!("server error: {err}");
        }
    }
    let wall = t0.elapsed();
    println!(
        "client: {requests} requests in {:.3}s ({:.1} img/s sequential)",
        wall.as_secs_f64(),
        requests as f64 / wall.as_secs_f64()
    );
    // fetch server-side metrics
    writeln!(writer, "{}", Json::obj(vec![("cmd", Json::Str("metrics".into()))]))?;
    line.clear();
    reader.read_line(&mut line)?;
    println!("server: {line}");
    Ok(0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::IMAGE_ELEMS;

    #[test]
    fn sweep_request_returns_cells() {
        let engine = Engine::analytics();
        let (reply, stop) = engine.handle_line(
            r#"{"cmd":"sweep","networks":["AlexNet"],"macs":[512,2048],
               "strategies":["optimal"],"modes":["passive","active"],"workers":2}"#,
        );
        assert!(!stop);
        assert_eq!(reply.get("count").unwrap().as_usize(), Some(4));
        let cells = reply.get("cells").unwrap().as_arr().unwrap();
        assert_eq!(cells.len(), 4);
        assert_eq!(cells[0].get("network").unwrap().as_str(), Some("AlexNet"));
        assert!(cells[0].get("total").unwrap().as_f64().unwrap() > 0.0);
    }

    #[test]
    fn sweep_request_accepts_fusion_depth() {
        let engine = Engine::analytics();
        let (reply, _) = engine.handle_line(
            r#"{"cmd":"sweep","networks":["AlexNet"],"macs":[512],
               "strategies":["optimal"],"modes":["passive"],"fusion_depth":[1,2]}"#,
        );
        let cells = reply.get("cells").unwrap().as_arr().unwrap();
        assert_eq!(cells.len(), 2);
        assert!(cells[0].get("fusion_depth").is_none());
        assert_eq!(cells[1].get("fusion_depth").unwrap().as_usize(), Some(2));
        let fused = cells[1].get("total").unwrap().as_f64().unwrap();
        let unfused = cells[0].get("total").unwrap().as_f64().unwrap();
        assert!(fused < unfused);
        let (reply, _) = engine.handle_line(r#"{"cmd":"sweep","fusion_depth":0}"#);
        assert!(reply.get("error").is_some());
        assert_eq!(reply.get("code").unwrap().as_str(), Some("bad_request"));
    }

    #[test]
    fn bind_port_zero_reports_the_actual_port() {
        let (listener, port) = bind(0).unwrap();
        assert_ne!(port, 0, "ephemeral bind must report the real port");
        assert_eq!(listener.local_addr().unwrap().port(), port);
        // A second ephemeral bind coexists: no fixed-port race.
        let (_other, other_port) = bind(0).unwrap();
        assert_ne!(other_port, 0);
        assert_ne!(other_port, port);
    }

    #[test]
    fn shutdown_unblocks_idle_connections() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let engine = Arc::new(Engine::analytics());
        let config = ServeConfig { workers: 4, queue: 8, max_conns: 64, timeout: None };
        let (tx, rx) = std::sync::mpsc::channel();
        let server = std::thread::spawn(move || {
            let result = serve_on(listener, &engine, &config);
            let _ = tx.send(());
            result
        });

        // An idle keep-alive client: connects, sends nothing, stays open.
        // Pre-fix, its worker thread blocked in the read loop forever and
        // `thread::scope` never returned.
        let idle = TcpStream::connect(addr).unwrap();
        std::thread::sleep(Duration::from_millis(50)); // let it park in read

        let ctl = TcpStream::connect(addr).unwrap();
        let mut writer = ctl.try_clone().unwrap();
        let mut reader = BufReader::new(ctl);
        let mut line = String::new();
        writeln!(writer, r#"{{"cmd":"metrics"}}"#).unwrap();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("metrics"), "{line}");
        line.clear();
        writeln!(writer, r#"{{"cmd":"shutdown"}}"#).unwrap();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("true"), "{line}");

        rx.recv_timeout(Duration::from_secs(10))
            .expect("server did not shut down while an idle connection was open");
        server.join().unwrap().unwrap();
        drop(idle);
    }

    #[test]
    fn sweep_cache_warms_across_requests() {
        let engine = Engine::analytics();
        let req = r#"{"cmd":"sweep","networks":["resnet18"],"macs":[1024],
                      "strategies":["optimal"],"modes":["passive"]}"#;
        let (first, _) = engine.handle_line(req);
        let (second, _) = engine.handle_line(req);
        // Per-request deltas: the first sweep populates the cache, the
        // second identical one computes nothing new.
        assert!(first.get("cache_misses").unwrap().as_f64().unwrap() > 0.0);
        assert_eq!(second.get("cache_misses").unwrap().as_f64().unwrap(), 0.0);
        assert!(second.get("cache_hits").unwrap().as_f64().unwrap() > 0.0);
    }

    #[test]
    fn explore_request_returns_frontier() {
        let engine = Engine::analytics();
        let (reply, _) = engine.handle_line(
            r#"{"cmd":"explore","networks":["AlexNet"],"macs":[512,1024],
               "sram":["unlimited","64k"],"strategies":["optimal"],
               "modes":["passive","active"],"workers":2}"#,
        );
        let frontier = reply.get("frontier").unwrap().as_arr().unwrap();
        assert!(!frontier.is_empty());
        assert_eq!(reply.get("count").unwrap().as_usize(), Some(frontier.len()));
        assert_eq!(reply.get("candidates").unwrap().as_usize(), Some(8));
        let evaluated = reply.get("evaluated").unwrap().as_usize().unwrap();
        let pruned = reply.get("pruned").unwrap().as_usize().unwrap();
        assert_eq!(evaluated + pruned, 8);
        assert_eq!(frontier[0].get("network").unwrap().as_str(), Some("AlexNet"));
        assert!(frontier[0].get("bandwidth").unwrap().as_f64().unwrap() > 0.0);
        // the same engine cache serves sweeps and explorations
        assert!(engine.cache_stats().1 > 0);
    }

    #[test]
    fn explore_request_validation() {
        let engine = Engine::analytics();
        for bad in [
            r#"{"cmd":"explore","networks":["Nope"]}"#,
            r#"{"cmd":"explore","sram":[0]}"#,
            r#"{"cmd":"explore","objectives":["latency"]}"#,
            r#"{"cmd":"explore","strategy":["optimal"]}"#,
        ] {
            let (reply, _) = engine.handle_line(bad);
            assert!(reply.get("error").is_some(), "accepted {bad}");
            assert_eq!(reply.get("code").unwrap().as_str(), Some("bad_request"), "{bad}");
        }
    }

    #[test]
    fn sweep_request_validation() {
        let engine = Engine::analytics();
        for bad in [
            r#"{"cmd":"sweep","networks":["Nope"]}"#,
            r#"{"cmd":"sweep","macs":[0]}"#,
            r#"{"cmd":"bogus"}"#,
            "not json",
        ] {
            let (reply, _) = engine.handle_line(bad);
            assert!(reply.get("error").is_some(), "accepted {bad}");
        }
    }

    #[test]
    fn inference_without_artifacts_is_a_clean_error() {
        let engine = Engine::analytics();
        let img = format!(
            r#"{{"image":[{}]}}"#,
            std::iter::repeat("0").take(IMAGE_ELEMS).collect::<Vec<_>>().join(",")
        );
        let (reply, _) = engine.handle_line(&img);
        let err = reply.get("error").unwrap().as_str().unwrap();
        assert!(err.contains("inference unavailable"), "{err}");
        assert_eq!(reply.get("code").unwrap().as_str(), Some("inference_unavailable"));
    }

    #[test]
    fn metrics_and_shutdown_work_without_service() {
        let engine = Engine::analytics();
        let (m, stop) = engine.handle_line(r#"{"cmd":"metrics"}"#);
        assert!(!stop);
        assert!(m.get("metrics").unwrap().as_str().unwrap().contains("disabled"));
        assert!(m.get("requests").is_some());
        let (s, stop) = engine.handle_line(r#"{"cmd":"shutdown"}"#);
        assert!(stop);
        assert_eq!(s.get("ok"), Some(&Json::Bool(true)));
    }

    #[test]
    fn version_request_reports_protocol() {
        let engine = Engine::analytics();
        let (v, _) = engine.handle_line(r#"{"cmd":"version"}"#);
        assert_eq!(v.get("protocol").unwrap().as_usize(), Some(crate::api::PROTOCOL_VERSION));
        assert_eq!(v.get("version").unwrap().as_str(), Some(crate::api::CRATE_VERSION));
    }
}
