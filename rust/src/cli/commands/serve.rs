//! `serve` / `client`: a TCP JSON-lines server + load generator.
//!
//! The server is a thin socket layer over [`crate::api::Engine`]: every
//! line is decoded, dispatched and encoded by the typed facade
//! ([`Engine::handle_line`]), so the wire protocol, the request-size
//! caps and the per-request metrics are exactly the ones every other
//! frontend (CLI commands, `psim request`, library embedders) gets.
//! When the PJRT artifacts are absent the server starts in
//! *analytics-only* mode: analytics commands work, inference requests
//! report `inference_unavailable`.
//!
//! Protocol (one JSON object per line): see the README's protocol table
//! (generated from [`crate::api::COMMANDS`]) or [`crate::api::codec`].
//! Errors reply `{"code": "...", "error": "..."}` with a stable
//! machine-readable code.

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{Shutdown, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{Context, Result};

use crate::api::Engine;
use crate::cli::args::Args;
use crate::runtime::Tensor;
use crate::util::json::Json;

/// Live connection sockets, so `{"cmd":"shutdown"}` can unblock peers
/// parked in a blocking read. Without this, `thread::scope` in
/// [`serve_on`] waits forever on idle keep-alive clients (their handler
/// threads sit in `reader.lines()` until the *client* hangs up).
#[derive(Default)]
struct ConnRegistry {
    conns: Mutex<HashMap<u64, TcpStream>>,
    next_id: AtomicU64,
}

impl ConnRegistry {
    /// Track a connection; returns the handle to deregister with.
    /// `None` (a failed `try_clone`) means the connection CANNOT be
    /// tracked — the caller must refuse to serve it, because an untracked
    /// idle reader would be unreachable by [`ConnRegistry::shutdown_all`]
    /// and reintroduce the shutdown hang.
    fn register(&self, stream: &TcpStream) -> Option<u64> {
        let clone = stream.try_clone().ok()?;
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        self.conns.lock().unwrap().insert(id, clone);
        Some(id)
    }

    fn deregister(&self, id: u64) {
        self.conns.lock().unwrap().remove(&id);
    }

    /// Shut down every tracked socket: blocked readers see EOF/error and
    /// their handler threads exit. Sockets stay registered until their
    /// handler deregisters; double-shutdown is harmless.
    fn shutdown_all(&self) {
        for conn in self.conns.lock().unwrap().values() {
            let _ = conn.shutdown(Shutdown::Both);
        }
    }
}

/// `psim serve [--port P] [--max-batch B]`
pub fn serve(args: &Args) -> Result<i32> {
    let port = args.opt_usize("port")?.unwrap_or(7878) as u16;
    let max_batch = args.opt_usize("max-batch")?.unwrap_or(8).clamp(1, 8);
    args.reject_unknown()?;

    let engine = Arc::new(Engine::start(max_batch)?);
    if let Some(err) = engine.inference_error() {
        eprintln!("psim serve: inference disabled ({err}); serving design-space queries only");
    }
    let listener =
        TcpListener::bind(("127.0.0.1", port)).with_context(|| format!("binding port {port}"))?;
    println!(
        "psim serve: listening on 127.0.0.1:{port} (max_batch={max_batch}, inference {})",
        if engine.has_inference() { "enabled" } else { "disabled" }
    );
    serve_on(listener, &engine)?;
    let (hits, misses) = engine.cache_stats();
    match engine.service_metrics() {
        Some(summary) => println!("psim serve: shut down. {summary}"),
        None => println!("psim serve: shut down. sweep cache {hits} hits / {misses} misses"),
    }
    Ok(0)
}

/// Accept loop: runs until a `{"cmd":"shutdown"}` request flips the flag.
/// Guaranteed to return even with idle keep-alive clients connected: the
/// shutting-down handler closes every registered socket, so no handler
/// thread can stay parked in a blocking read (regression-tested by
/// `shutdown_unblocks_idle_connections`).
fn serve_on(listener: TcpListener, engine: &Arc<Engine>) -> Result<()> {
    let shutdown = Arc::new(AtomicBool::new(false));
    let registry = Arc::new(ConnRegistry::default());

    std::thread::scope(|scope| -> Result<()> {
        for stream in listener.incoming() {
            if shutdown.load(Ordering::SeqCst) {
                break;
            }
            let stream = stream?;
            let engine = engine.clone();
            let shutdown = shutdown.clone();
            let registry = registry.clone();
            scope.spawn(move || {
                if let Err(e) = handle_conn(stream, &engine, &shutdown, &registry) {
                    eprintln!("psim serve: connection error: {e:#}");
                }
            });
        }
        Ok(())
    })
}

fn handle_conn(
    stream: TcpStream,
    engine: &Engine,
    shutdown: &AtomicBool,
    registry: &ConnRegistry,
) -> Result<()> {
    let Some(id) = registry.register(&stream) else {
        // Untrackable (try_clone failed, e.g. fd exhaustion): refuse the
        // connection rather than serve a socket shutdown can't reach.
        return Ok(());
    };
    // A connection accepted in the shutdown race window is never served:
    // the flag is set before `shutdown_all`, so either our socket was
    // already shut or we observe the flag here.
    let result = if shutdown.load(Ordering::SeqCst) {
        Ok(())
    } else {
        conn_loop(stream, engine, shutdown, registry)
    };
    registry.deregister(id);
    result
}

/// One connection's request/reply loop: read a line, let the engine
/// decode + dispatch + encode it, write the reply.
fn conn_loop(
    stream: TcpStream,
    engine: &Engine,
    shutdown: &AtomicBool,
    registry: &ConnRegistry,
) -> Result<()> {
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = match line {
            Ok(line) => line,
            // A peer unblocked by shutdown_all surfaces a read error
            // (or EOF, which ends the iterator) — not a failure.
            Err(_) if shutdown.load(Ordering::SeqCst) => break,
            Err(e) => return Err(e.into()),
        };
        if line.trim().is_empty() {
            continue;
        }
        let (reply, stop) = engine.handle_line(&line);
        if stop {
            shutdown.store(true, Ordering::SeqCst);
        }
        if let Err(e) = writeln!(writer, "{reply}") {
            // A write aborted by shutdown_all (broken pipe) is part of a
            // clean shutdown, not a connection error.
            if shutdown.load(Ordering::SeqCst) {
                break;
            }
            return Err(e.into());
        }
        if shutdown.load(Ordering::SeqCst) {
            // Poke the accept loop so it observes the flag, then unblock
            // every other connection's parked reader.
            let _ = TcpStream::connect(writer.local_addr()?);
            registry.shutdown_all();
            break;
        }
    }
    Ok(())
}

/// `psim client [--port P] [--requests N]` — fire N random images at a
/// running server and report client-observed latency/throughput.
pub fn client(args: &Args) -> Result<i32> {
    let port = args.opt_usize("port")?.unwrap_or(7878) as u16;
    let requests = args.opt_usize("requests")?.unwrap_or(16);
    args.reject_unknown()?;

    let stream = TcpStream::connect(("127.0.0.1", port))
        .with_context(|| format!("connecting to 127.0.0.1:{port} — is `psim serve` running?"))?;
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);

    let t0 = std::time::Instant::now();
    let mut line = String::new();
    for i in 0..requests {
        let img = Tensor::random(&[3, 32, 32], i as u64, 1.0);
        let payload = Json::obj(vec![(
            "image",
            Json::Arr(img.data.iter().map(|&v| Json::Num(v as f64)).collect()),
        )]);
        writeln!(writer, "{payload}")?;
        line.clear();
        reader.read_line(&mut line)?;
        let resp = Json::parse(&line).map_err(|e| anyhow::anyhow!("bad response: {e}"))?;
        if let Some(err) = resp.get("error") {
            anyhow::bail!("server error: {err}");
        }
    }
    let wall = t0.elapsed();
    println!(
        "client: {requests} requests in {:.3}s ({:.1} img/s sequential)",
        wall.as_secs_f64(),
        requests as f64 / wall.as_secs_f64()
    );
    // fetch server-side metrics
    writeln!(writer, "{}", Json::obj(vec![("cmd", Json::Str("metrics".into()))]))?;
    line.clear();
    reader.read_line(&mut line)?;
    println!("server: {line}");
    Ok(0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::IMAGE_ELEMS;

    #[test]
    fn sweep_request_returns_cells() {
        let engine = Engine::analytics();
        let (reply, stop) = engine.handle_line(
            r#"{"cmd":"sweep","networks":["AlexNet"],"macs":[512,2048],
               "strategies":["optimal"],"modes":["passive","active"],"workers":2}"#,
        );
        assert!(!stop);
        assert_eq!(reply.get("count").unwrap().as_usize(), Some(4));
        let cells = reply.get("cells").unwrap().as_arr().unwrap();
        assert_eq!(cells.len(), 4);
        assert_eq!(cells[0].get("network").unwrap().as_str(), Some("AlexNet"));
        assert!(cells[0].get("total").unwrap().as_f64().unwrap() > 0.0);
    }

    #[test]
    fn sweep_request_accepts_fusion_depth() {
        let engine = Engine::analytics();
        let (reply, _) = engine.handle_line(
            r#"{"cmd":"sweep","networks":["AlexNet"],"macs":[512],
               "strategies":["optimal"],"modes":["passive"],"fusion_depth":[1,2]}"#,
        );
        let cells = reply.get("cells").unwrap().as_arr().unwrap();
        assert_eq!(cells.len(), 2);
        assert!(cells[0].get("fusion_depth").is_none());
        assert_eq!(cells[1].get("fusion_depth").unwrap().as_usize(), Some(2));
        let fused = cells[1].get("total").unwrap().as_f64().unwrap();
        let unfused = cells[0].get("total").unwrap().as_f64().unwrap();
        assert!(fused < unfused);
        let (reply, _) = engine.handle_line(r#"{"cmd":"sweep","fusion_depth":0}"#);
        assert!(reply.get("error").is_some());
        assert_eq!(reply.get("code").unwrap().as_str(), Some("bad_request"));
    }

    #[test]
    fn shutdown_unblocks_idle_connections() {
        use std::time::Duration;

        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let engine = Arc::new(Engine::analytics());
        let (tx, rx) = std::sync::mpsc::channel();
        let server = std::thread::spawn(move || {
            let result = serve_on(listener, &engine);
            let _ = tx.send(());
            result
        });

        // An idle keep-alive client: connects, sends nothing, stays open.
        // Pre-fix, its handler thread blocked in `reader.lines()` forever
        // and `thread::scope` never returned.
        let idle = TcpStream::connect(addr).unwrap();
        std::thread::sleep(Duration::from_millis(50)); // let it park in read

        let ctl = TcpStream::connect(addr).unwrap();
        let mut writer = ctl.try_clone().unwrap();
        let mut reader = BufReader::new(ctl);
        let mut line = String::new();
        writeln!(writer, r#"{{"cmd":"metrics"}}"#).unwrap();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("metrics"), "{line}");
        line.clear();
        writeln!(writer, r#"{{"cmd":"shutdown"}}"#).unwrap();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("true"), "{line}");

        rx.recv_timeout(Duration::from_secs(10))
            .expect("server did not shut down while an idle connection was open");
        server.join().unwrap().unwrap();
        drop(idle);
    }

    #[test]
    fn sweep_cache_warms_across_requests() {
        let engine = Engine::analytics();
        let req = r#"{"cmd":"sweep","networks":["resnet18"],"macs":[1024],
                      "strategies":["optimal"],"modes":["passive"]}"#;
        let (first, _) = engine.handle_line(req);
        let (second, _) = engine.handle_line(req);
        // Per-request deltas: the first sweep populates the cache, the
        // second identical one computes nothing new.
        assert!(first.get("cache_misses").unwrap().as_f64().unwrap() > 0.0);
        assert_eq!(second.get("cache_misses").unwrap().as_f64().unwrap(), 0.0);
        assert!(second.get("cache_hits").unwrap().as_f64().unwrap() > 0.0);
    }

    #[test]
    fn explore_request_returns_frontier() {
        let engine = Engine::analytics();
        let (reply, _) = engine.handle_line(
            r#"{"cmd":"explore","networks":["AlexNet"],"macs":[512,1024],
               "sram":["unlimited","64k"],"strategies":["optimal"],
               "modes":["passive","active"],"workers":2}"#,
        );
        let frontier = reply.get("frontier").unwrap().as_arr().unwrap();
        assert!(!frontier.is_empty());
        assert_eq!(reply.get("count").unwrap().as_usize(), Some(frontier.len()));
        assert_eq!(reply.get("candidates").unwrap().as_usize(), Some(8));
        let evaluated = reply.get("evaluated").unwrap().as_usize().unwrap();
        let pruned = reply.get("pruned").unwrap().as_usize().unwrap();
        assert_eq!(evaluated + pruned, 8);
        assert_eq!(frontier[0].get("network").unwrap().as_str(), Some("AlexNet"));
        assert!(frontier[0].get("bandwidth").unwrap().as_f64().unwrap() > 0.0);
        // the same engine cache serves sweeps and explorations
        assert!(engine.cache_stats().1 > 0);
    }

    #[test]
    fn explore_request_validation() {
        let engine = Engine::analytics();
        for bad in [
            r#"{"cmd":"explore","networks":["Nope"]}"#,
            r#"{"cmd":"explore","sram":[0]}"#,
            r#"{"cmd":"explore","objectives":["latency"]}"#,
            r#"{"cmd":"explore","strategy":["optimal"]}"#,
        ] {
            let (reply, _) = engine.handle_line(bad);
            assert!(reply.get("error").is_some(), "accepted {bad}");
            assert_eq!(reply.get("code").unwrap().as_str(), Some("bad_request"), "{bad}");
        }
    }

    #[test]
    fn sweep_request_validation() {
        let engine = Engine::analytics();
        for bad in [
            r#"{"cmd":"sweep","networks":["Nope"]}"#,
            r#"{"cmd":"sweep","macs":[0]}"#,
            r#"{"cmd":"bogus"}"#,
            "not json",
        ] {
            let (reply, _) = engine.handle_line(bad);
            assert!(reply.get("error").is_some(), "accepted {bad}");
        }
    }

    #[test]
    fn inference_without_artifacts_is_a_clean_error() {
        let engine = Engine::analytics();
        let img = format!(
            r#"{{"image":[{}]}}"#,
            std::iter::repeat("0").take(IMAGE_ELEMS).collect::<Vec<_>>().join(",")
        );
        let (reply, _) = engine.handle_line(&img);
        let err = reply.get("error").unwrap().as_str().unwrap();
        assert!(err.contains("inference unavailable"), "{err}");
        assert_eq!(reply.get("code").unwrap().as_str(), Some("inference_unavailable"));
    }

    #[test]
    fn metrics_and_shutdown_work_without_service() {
        let engine = Engine::analytics();
        let (m, stop) = engine.handle_line(r#"{"cmd":"metrics"}"#);
        assert!(!stop);
        assert!(m.get("metrics").unwrap().as_str().unwrap().contains("disabled"));
        assert!(m.get("requests").is_some());
        let (s, stop) = engine.handle_line(r#"{"cmd":"shutdown"}"#);
        assert!(stop);
        assert_eq!(s.get("ok"), Some(&Json::Bool(true)));
    }

    #[test]
    fn version_request_reports_protocol() {
        let engine = Engine::analytics();
        let (v, _) = engine.handle_line(r#"{"cmd":"version"}"#);
        assert_eq!(v.get("protocol").unwrap().as_usize(), Some(crate::api::PROTOCOL_VERSION));
        assert_eq!(v.get("version").unwrap().as_str(), Some(crate::api::CRATE_VERSION));
    }
}
