//! `cache`: inspect and maintain a result-store artifact directory.
//!
//! Three actions over the `--store DIR` artifact directory that `serve`
//! and `psim request` write:
//!
//! - `ls` — one line per artifact (digest, validity, size, command);
//! - `verify` — validate every artifact, exit 1 if any is invalid;
//! - `gc` — delete invalid artifacts (valid ones are never touched;
//!   re-derived caches need no age-based expiry).
//!
//! The artifact directory is hostile input by definition — anything can
//! have rewritten those files — so this module is on the psim-lint
//! PS100 panic-freedom list and every malformed artifact is reported,
//! never unwrapped.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::cli::args::Args;
use crate::store::artifact::{self, ArtifactState};
use crate::util::json::Json;

/// `psim cache <ls|verify|gc> --store DIR` — the action token is parsed
/// here (the flag parser takes options only), then the rest goes
/// through [`Args`] so unknown flags fail like every other command.
pub fn cache(argv: &[String]) -> Result<i32> {
    let action = match argv.first().map(String::as_str) {
        Some(a @ ("ls" | "verify" | "gc")) => a,
        Some(other) => {
            bail!("unknown cache action '{other}' — usage: psim cache <ls|verify|gc> --store DIR")
        }
        None => bail!("usage: psim cache <ls|verify|gc> --store DIR"),
    };
    let mut reshaped = vec![format!("cache {action}")];
    reshaped.extend(argv.iter().skip(1).cloned());
    let args = Args::parse(&reshaped)?;
    let Some(dir) = args.opt("store").map(str::to_string) else {
        bail!("psim cache {action}: --store DIR is required");
    };
    args.reject_unknown()?;

    let dir = Path::new(&dir);
    let entries = artifact::scan(dir)
        .with_context(|| format!("scanning result store '{}'", dir.display()))?;
    match action {
        "ls" => ls(&entries),
        "verify" => verify(&entries),
        _ => gc(&entries),
    }
}

/// The `cmd` of an artifact's canonical request, for the listing.
fn canonical_cmd(manifest: &artifact::Manifest) -> String {
    Json::parse(&manifest.canonical)
        .ok()
        .and_then(|json| json.get("cmd").and_then(Json::as_str).map(str::to_string))
        .unwrap_or_else(|| "?".to_string())
}

fn file_label(path: &Path) -> String {
    path.file_name().map(|n| n.to_string_lossy().to_string()).unwrap_or_default()
}

fn ls(entries: &[(std::path::PathBuf, ArtifactState)]) -> Result<i32> {
    let mut invalid = 0usize;
    for (path, state) in entries {
        let bytes = std::fs::metadata(path).map(|m| m.len()).unwrap_or(0);
        match state {
            ArtifactState::Valid { manifest, .. } => {
                println!(
                    "{}  valid    {:>9} B  cmd={}  created_unix={}",
                    file_label(path),
                    bytes,
                    canonical_cmd(manifest),
                    manifest.created_unix
                );
            }
            ArtifactState::Invalid { reason } => {
                invalid += 1;
                println!("{}  INVALID  {:>9} B  {reason}", file_label(path), bytes);
            }
        }
    }
    println!("{} artifacts, {} valid, {invalid} invalid", entries.len(), entries.len() - invalid);
    Ok(0)
}

fn verify(entries: &[(std::path::PathBuf, ArtifactState)]) -> Result<i32> {
    let mut invalid = 0usize;
    for (path, state) in entries {
        if let ArtifactState::Invalid { reason } = state {
            invalid += 1;
            eprintln!("psim cache verify: {}: {reason}", file_label(path));
        }
    }
    println!(
        "psim cache verify: {} artifacts, {} valid, {invalid} invalid",
        entries.len(),
        entries.len() - invalid
    );
    Ok(if invalid == 0 { 0 } else { 1 })
}

fn gc(entries: &[(std::path::PathBuf, ArtifactState)]) -> Result<i32> {
    let mut removed = 0usize;
    for (path, state) in entries {
        if let ArtifactState::Invalid { reason } = state {
            std::fs::remove_file(path)
                .with_context(|| format!("removing invalid artifact {}", path.display()))?;
            removed += 1;
            println!("psim cache gc: removed {} ({reason})", file_label(path));
        }
    }
    println!("psim cache gc: removed {removed} of {} artifacts", entries.len());
    Ok(0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::fs;
    use std::path::PathBuf;

    fn sv(parts: &[&str]) -> Vec<String> {
        parts.iter().map(|s| s.to_string()).collect()
    }

    fn temp_store(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "psim_cache_cmd_{tag}_{}_{}",
            std::process::id(),
            artifact::now_unix()
        ));
        fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn actions_require_a_store_dir_and_valid_action() {
        assert!(cache(&sv(&[])).is_err());
        assert!(cache(&sv(&["frobnicate"])).is_err());
        assert!(cache(&sv(&["ls"])).is_err());
        assert!(cache(&sv(&["ls", "--frobnicate", "x"])).is_err());
    }

    #[test]
    fn verify_exits_nonzero_on_corruption_and_gc_removes_it() {
        let dir = temp_store("verify_gc");
        let store_flag = dir.to_str().unwrap().to_string();
        artifact::write(&dir, "req-good", "reply-good").unwrap();
        let bad = artifact::write(&dir, "req-bad", "reply-bad").unwrap();
        // Corrupt the payload without updating the checksum.
        let text = fs::read_to_string(&bad).unwrap().replace("reply-bad", "reply-EVIL");
        fs::write(&bad, text).unwrap();

        assert_eq!(cache(&sv(&["ls", "--store", &store_flag])).unwrap(), 0);
        assert_eq!(cache(&sv(&["verify", "--store", &store_flag])).unwrap(), 1);
        assert_eq!(cache(&sv(&["gc", "--store", &store_flag])).unwrap(), 0);
        // The corrupt artifact is gone, the valid one survived.
        assert!(!bad.exists());
        assert_eq!(cache(&sv(&["verify", "--store", &store_flag])).unwrap(), 0);
        assert_eq!(artifact::scan(&dir).unwrap().len(), 1);
        fs::remove_dir_all(&dir).ok();
    }
}
