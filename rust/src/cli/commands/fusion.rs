//! `fusion`: per-network fused-vs-unfused bandwidth report — the
//! [`crate::report::fusion`] table from the command line, via the same
//! [`crate::api::Engine`] dispatch the `serve` protocol uses.

use anyhow::Result;

use crate::analytics::bandwidth::ControllerMode;
use crate::analytics::partition::Strategy;
use crate::api::{Engine, Request, Response};
use crate::cli::args::Args;
use crate::config::accel::{parse_mode, parse_strategy};
use crate::models::zoo;

use super::sweep::resolve_network;

/// `psim fusion [--networks a,b] [--depth N] [--macs P] [--strategy S]
/// [--mode passive|active] [--bits 8:8:32:8] [--csv] [--faithful]`
///
/// Renders the fused-vs-unfused comparison: chains of up to `--depth`
/// consecutive layers keep intermediates on chip; the table shows each
/// network's chain structure and the activation traffic saved.
pub fn fusion(args: &Args) -> Result<i32> {
    let faithful = args.flag("faithful");
    let networks = match args.opt("networks") {
        Some(list) => list
            .split(',')
            .map(|raw| resolve_network(raw.trim(), faithful))
            .collect::<Result<Vec<_>>>()?,
        None => {
            if faithful {
                zoo::faithful_networks()
            } else {
                zoo::paper_networks()
            }
        }
    };
    let depth = args.opt_usize("depth")?.unwrap_or(2);
    let p_macs = args.opt_usize("macs")?.unwrap_or(1024);
    let strategy = match args.opt("strategy") {
        Some(s) => parse_strategy(s)?,
        None => Strategy::Optimal,
    };
    let mode = match args.opt("mode") {
        Some(m) => parse_mode(m)?,
        None => ControllerMode::Passive,
    };
    let dt = super::analyze::opt_bits_from(args)?.unwrap_or_default();
    let csv = args.flag("csv");
    args.reject_unknown()?;

    let engine = Engine::analytics();
    let resp =
        engine.dispatch(&Request::Fusion { networks, depth, p_macs, strategy, mode, dt })?;
    let Response::Table { table, note } = resp else {
        unreachable!("fusion dispatch always returns a table response")
    };
    print!("{}", if csv { table.to_csv() } else { table.to_markdown() });
    eprintln!("{note}");
    Ok(0)
}
