//! `infer`: closed-loop batched inference benchmark over the PJRT stack.

use std::time::Instant;

use anyhow::Result;

use crate::cli::args::Args;
use crate::coordinator::{InferenceService, ServiceConfig};
use crate::runtime::{ArtifactDir, Tensor};

/// `psim infer [--requests N] [--concurrency C] [--max-batch B] [--seed S]`
///
/// Spawns C client threads that each fire requests back-to-back until N
/// total responses arrive; reports throughput, latency percentiles and
/// the realized batch-size distribution.
pub fn infer(args: &Args) -> Result<i32> {
    let requests = args.opt_usize("requests")?.unwrap_or(64);
    let concurrency = args.opt_usize("concurrency")?.unwrap_or(8).max(1);
    let max_batch = args.opt_usize("max-batch")?.unwrap_or(8).clamp(1, 8);
    let seed = args.opt_usize("seed")?.unwrap_or(42) as u64;
    args.reject_unknown()?;

    let artifacts = ArtifactDir::open_default()?;
    println!(
        "artifacts: {} ({} entries, fingerprint {})",
        artifacts.dir.display(),
        artifacts.entries.len(),
        artifacts.fingerprint
    );
    let cfg = ServiceConfig {
        max_batch,
        weight_seed: seed,
        ..ServiceConfig::default()
    };
    let service = InferenceService::start(artifacts, cfg)?;

    // Warm up (compilation happens on the engine thread's first loads).
    let warm = service.infer(Tensor::random(&[3, 32, 32], seed, 1.0))?;
    println!("warmup: class={} latency={}us", warm.top_class(), warm.latency_us);

    let t0 = Instant::now();
    let per_client = requests.div_ceil(concurrency);
    std::thread::scope(|scope| {
        for c in 0..concurrency {
            let service = &service;
            scope.spawn(move || {
                for i in 0..per_client {
                    let img = Tensor::random(&[3, 32, 32], seed ^ ((c * 1000 + i) as u64), 1.0);
                    let _ = service.infer(img);
                }
            });
        }
    });
    let wall = t0.elapsed();

    let m = &service.metrics;
    let served = per_client * concurrency;
    println!("\n== e2e inference over PJRT (PsimNet, batch<= {max_batch}) ==");
    println!("requests          : {served}");
    println!("wall time         : {:.3} s", wall.as_secs_f64());
    println!("throughput        : {:.1} img/s", served as f64 / wall.as_secs_f64());
    println!("metrics           : {}", m.summary());
    Ok(0)
}
