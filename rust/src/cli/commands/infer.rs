//! `infer`: closed-loop batched inference benchmark over the PJRT stack.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::Instant;

use anyhow::Result;

use crate::cli::args::Args;
use crate::coordinator::parallel::split_shares;
use crate::coordinator::{InferenceService, ServiceConfig};
use crate::runtime::{ArtifactDir, Tensor};

/// `psim infer [--requests N] [--concurrency C] [--max-batch B] [--seed S]`
///
/// Spawns C client threads that together fire exactly N requests
/// back-to-back (the remainder of N/C is spread one-per-client, not
/// rounded up); reports failures separately and computes throughput from
/// the requests actually served.
pub fn infer(args: &Args) -> Result<i32> {
    let requests = args.opt_usize("requests")?.unwrap_or(64);
    let concurrency = args.opt_usize("concurrency")?.unwrap_or(8).max(1);
    let max_batch = args.opt_usize("max-batch")?.unwrap_or(8).clamp(1, 8);
    let seed = args.opt_usize("seed")?.unwrap_or(42) as u64;
    args.reject_unknown()?;

    let artifacts = ArtifactDir::open_default()?;
    println!(
        "artifacts: {} ({} entries, fingerprint {})",
        artifacts.dir.display(),
        artifacts.entries.len(),
        artifacts.fingerprint
    );
    let cfg = ServiceConfig {
        max_batch,
        weight_seed: seed,
        ..ServiceConfig::default()
    };
    let service = InferenceService::start(artifacts, cfg)?;

    // Warm up (compilation happens on the engine thread's first loads).
    let warm = service.infer(Tensor::random(&[3, 32, 32], seed, 1.0))?;
    println!("warmup: class={} latency={}us", warm.top_class(), warm.latency_us);

    let t0 = Instant::now();
    let failures = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        // Exact distribution (shared with `psim bench`): the first
        // `requests % concurrency` clients take one extra request; the
        // total is always N.
        for (c, n) in split_shares(requests, concurrency).into_iter().enumerate() {
            let service = &service;
            let failures = &failures;
            scope.spawn(move || {
                for i in 0..n {
                    // Collision-free per-request seed: client id in the
                    // high bits, request index in the low bits.
                    let mix = ((c as u64) << 32) | i as u64;
                    let img = Tensor::random(&[3, 32, 32], seed ^ mix, 1.0);
                    if service.infer(img).is_err() {
                        failures.fetch_add(1, Ordering::Relaxed);
                    }
                }
            });
        }
    });
    let wall = t0.elapsed();

    let m = &service.metrics;
    let failed = failures.into_inner();
    let served = requests - failed;
    println!("\n== e2e inference over PJRT (PsimNet, batch<= {max_batch}) ==");
    println!("requests          : {requests}");
    println!("served            : {served}");
    println!("failed            : {failed}");
    println!("wall time         : {:.3} s", wall.as_secs_f64());
    println!("throughput        : {:.1} img/s", served as f64 / wall.as_secs_f64());
    println!("metrics           : {}", m.summary());
    Ok(if failed == 0 { 0 } else { 1 })
}
