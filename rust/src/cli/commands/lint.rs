//! `lint`: the repo-invariant static analyzer (`psim-lint`).
//!
//! Runs the full pass registry (see `docs/LINTS.md`) over the repo
//! tree: panic freedom on the hostile-input modules, overflow-safe size
//! accounting, metrics-catalog and protocol sync, the format gate, and
//! the orphan-golden sweep. Exit code 0 means zero non-allowlisted
//! findings — CI gates on exactly that with `--json`.

use std::path::PathBuf;

use anyhow::{bail, Result};

use crate::cli::args::Args;
use crate::lint::{self, LintConfig};

/// `psim lint [--json] [--fix-hints] [--root DIR]`
pub fn lint(args: &Args) -> Result<i32> {
    let json = args.flag("json");
    let fix_hints = args.flag("fix-hints");
    let root = PathBuf::from(args.opt("root").unwrap_or("."));
    args.reject_unknown()?;
    if !root.join("rust/src").is_dir() {
        bail!(
            "{} does not look like the repo root (no rust/src/) — \
             run from the repo root or pass --root DIR",
            root.display()
        );
    }

    let report = lint::run(&LintConfig::repo(&root))?;
    if json {
        println!("{}", report.to_json());
    } else {
        for f in &report.findings {
            println!("{}:{}:{}: {} {}", f.path, f.line, f.col, f.code, f.message);
            if fix_hints {
                println!("    hint: {}", lint::hint_for(f.code));
            }
        }
        eprintln!(
            "psim lint: {} finding(s) across {} files",
            report.findings.len(),
            report.files_scanned
        );
    }
    Ok(if report.findings.is_empty() { 0 } else { 1 })
}
