//! `simulate` and `simsweep`: the event-level simulator from the CLI.
//! (The analytical grid sweep lives in [`super::sweep`]; `simsweep` is
//! its simulator-backed counterpart, adding the counters only the
//! event-level machine can produce — energy, cycles, MAC utilization.)

use anyhow::{anyhow, Result};

use crate::analytics::bandwidth::ControllerMode;
use crate::analytics::partition::Strategy;
use crate::analytics::sweep::network_bandwidth;
use crate::cli::args::Args;
use crate::config::{AccelConfig, ConfigDoc};
use crate::coordinator::parallel::{default_workers, parallel_map};
use crate::models::zoo;
use crate::sim::scheduler::{simulate_layer, simulate_network, simulate_network_detailed, SimConfig};
use crate::util::tablefmt::{mact, pct, Table};

use super::analyze::{mode_from, strategy_from};

/// `psim simulate --network NAME [--macs P] [--mode M] [--strategy S]
/// [--bits 8:8:32:8] [--config FILE] [--trace]`
///
/// `--bits` prices each region (ifmap/weight/psum/ofmap) at its own
/// width on the bus, the SRAM banks and the energy model, and reports
/// the byte traffic next to the element counts.
pub fn simulate(args: &Args) -> Result<i32> {
    let name = args.opt("network").ok_or_else(|| anyhow!("--network is required"))?.to_string();
    let mut accel = match args.opt("config") {
        Some(path) => AccelConfig::from_doc(&ConfigDoc::load(std::path::Path::new(path))?)?,
        None => AccelConfig::default(),
    };
    if let Some(p) = args.opt_usize("macs")? {
        accel.p_macs = p;
    }
    if args.opt("mode").is_some() {
        accel.mode = mode_from(args)?;
    }
    if args.opt("strategy").is_some() {
        accel.strategy = strategy_from(args)?;
    }
    let dt = super::analyze::opt_bits_from(args)?;
    let trace = args.flag("trace");
    args.reject_unknown()?;

    let net = zoo::by_name(&name)
        .ok_or_else(|| anyhow!("unknown network '{name}' — see `psim networks`"))?;
    let mut cfg = accel.sim_config();
    if let Some(dt) = &dt {
        cfg.bus.region_bits = Some(crate::sim::interconnect::RegionBits::from_datatypes(dt));
    }
    if trace {
        cfg.trace_cap = 64;
    }

    // One pass over the network; with --trace the per-layer results are
    // kept so their ring buffers can be dumped without re-simulating.
    let (r, layer_results) = if trace {
        simulate_network_detailed(&net, &cfg)
    } else {
        (simulate_network(&net, &cfg), Vec::new())
    };
    let s = &r.stats;
    let analytic = network_bandwidth(&net, accel.p_macs, accel.strategy, accel.mode).total();
    println!(
        "== {} on P={} ({} controller, {} strategy) ==",
        net.name,
        accel.p_macs,
        accel.mode.label(),
        accel.strategy.label()
    );
    println!(
        "activation traffic : {} M (analytical model: {} M)",
        mact(s.activation_traffic() as f64, 3),
        mact(analytic, 3)
    );
    println!("  input reads      : {} M", mact(s.input_reads as f64, 3));
    println!("  psum reads (bus) : {} M", mact(s.psum_reads as f64, 3));
    println!("  psum writes      : {} M", mact(s.psum_writes as f64, 3));
    println!(
        "  psum reads (ctrl): {} M  <- absorbed by the active controller",
        mact(s.internal_psum_reads as f64, 3)
    );
    if let Some(dt) = &dt {
        println!(
            "activation bytes   : {} MB on the wire (bits {})",
            mact(s.activation_bytes(dt), 3),
            dt.label()
        );
    }
    println!("weight reads       : {} M", mact(s.weight_reads as f64, 3));
    println!(
        "bus                : {} beats, {} bursts, {} sideband words",
        s.bus_beats, s.bus_transactions, s.sideband_words
    );
    println!("sram accesses      : {} M", mact(s.sram_accesses as f64, 3));
    println!(
        "macs               : {:.3} G ({} cycles, {:.1}% array utilization)",
        s.macs as f64 / 1e9,
        s.compute_cycles,
        s.mac_utilization(accel.p_macs) * 100.0
    );
    println!(
        "cycles             : {} (compute {}, bus {})",
        s.total_cycles(),
        s.compute_cycles,
        s.bus_cycles
    );
    println!("energy             : {:.3} mJ", s.energy_pj / 1e9);
    if trace {
        // Per-layer transaction dumps. The ring keeps the *last*
        // `trace_cap` events per layer; evicted counts are reported so a
        // truncated trace is visible instead of silently capped.
        println!(
            "trace              : ring cap {} events/layer, {} dropped in total",
            cfg.trace_cap, s.trace_dropped
        );
        for (layer, lr) in net.layers.iter().zip(&layer_results) {
            println!(
                "-- trace {} ({} events kept, {} dropped) --",
                layer.name,
                lr.trace.events().len(),
                lr.trace.dropped()
            );
            print!("{}", lr.trace.dump());
        }
    }
    let d = (s.activation_traffic() as f64 - analytic).abs() / analytic.max(1.0);
    println!("sim-vs-model delta : {}", pct(d));
    if d > 1e-9 {
        eprintln!("WARNING: simulator diverged from the analytical model");
        return Ok(2);
    }
    Ok(0)
}

/// `psim simsweep [--networks a,b] [--macs 512,...] [--strategy S]
/// [--mode M]` — the simulator-backed bulk sweep.
/// CSV: network,p_macs,mode,strategy,total_mact,input_mact,output_mact,
///      energy_mj,cycles,mac_util
pub fn simsweep(args: &Args) -> Result<i32> {
    let networks: Vec<String> = match args.opt("networks") {
        Some(list) => list.split(',').map(|s| s.trim().to_string()).collect(),
        None => zoo::paper_networks().iter().map(|n| n.name.clone()).collect(),
    };
    let macs = args
        .opt_usize_list("macs")?
        .unwrap_or_else(|| vec![512, 1024, 2048, 4096, 8192, 16384]);
    let strategy = strategy_from(args)?;
    let mode = mode_from(args)?;
    args.reject_unknown()?;

    let mut jobs = Vec::new();
    for name in &networks {
        let net = zoo::by_name(name)
            .ok_or_else(|| anyhow!("unknown network '{name}' — see `psim networks`"))?;
        for &p in &macs {
            jobs.push((net.clone(), p));
        }
    }
    let rows = parallel_map(&jobs, default_workers(), |(net, p)| {
        let cfg = SimConfig::new(*p, mode, strategy);
        let r = simulate_network(net, &cfg);
        let s = r.stats;
        vec![
            net.name.clone(),
            p.to_string(),
            mode.label().to_string(),
            strategy.label().to_string(),
            mact(s.activation_traffic() as f64, 3),
            mact(s.input_reads as f64, 3),
            mact(s.output_traffic() as f64, 3),
            format!("{:.3}", s.energy_pj / 1e9),
            s.total_cycles().to_string(),
            format!("{:.3}", s.mac_utilization(*p)),
        ]
    });
    let mut t = Table::new(vec![
        "network", "p_macs", "mode", "strategy", "total_mact", "input_mact", "output_mact",
        "energy_mj", "cycles", "mac_util",
    ]);
    for row in rows {
        t.row(row);
    }
    print!("{}", t.to_csv());
    Ok(0)
}

/// Exposed for the per-layer bench: simulate one named layer.
pub fn simulate_one_layer(net_name: &str, layer_name: &str, p: usize) -> Result<u64> {
    let net = zoo::by_name(net_name).ok_or_else(|| anyhow!("unknown network"))?;
    let layer = net.layer(layer_name).ok_or_else(|| anyhow!("unknown layer"))?;
    let cfg = crate::sim::scheduler::SimConfig::new(p, ControllerMode::Passive, Strategy::Optimal);
    Ok(simulate_layer(layer, &cfg).stats.activation_traffic())
}
