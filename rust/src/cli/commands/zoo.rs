//! `zoo`: the operator-aware network listing.
//!
//! Unlike `networks` (lowered-layer totals for the paper/faithful
//! profiles), `zoo` reports the typed operator view: per-op kind counts
//! (conv/gemm/attention), true parameter counts, and activation totals
//! — the same table the `{"cmd":"zoo"}` protocol request returns.

use anyhow::Result;

use crate::api::{Engine, Request, Response};
use crate::cli::args::Args;

/// `psim zoo [--csv]` — every registered network through the same
/// engine dispatch the protocol's `{"cmd":"zoo"}` uses.
pub fn zoo(args: &Args) -> Result<i32> {
    let csv = args.flag("csv");
    args.reject_unknown()?;
    let engine = Engine::analytics();
    let Response::Table { table, note } = engine.dispatch(&Request::Zoo)? else {
        unreachable!("zoo dispatch always returns a table response")
    };
    if csv {
        print!("{}", table.to_csv());
    } else {
        print!("{}", table.to_markdown());
    }
    println!("\n{note}");
    Ok(0)
}
