//! `request`: one-shot protocol dispatch — the `serve` wire protocol
//! without a socket. Reads JSON request lines (from `--json` or stdin),
//! dispatches each through [`crate::api::Engine::handle_line`] and prints
//! the JSON replies. Used by the CI protocol-golden smoke step and handy
//! for scripting (`printf '{"cmd":"version"}' | psim request`).
//!
//! Runs on an analytics-only engine — deliberately: replies stay
//! byte-deterministic regardless of whether `artifacts/` exists (the CI
//! fixtures depend on that), and a version query never pays a model
//! load. Inference requests report `inference_unavailable`; use
//! `psim serve` / `psim client` for the PJRT path.

use std::io::BufRead;

use anyhow::Result;

use crate::api::Engine;
use crate::cli::args::Args;

/// `psim request [--json LINE]`
///
/// Errors are replies too (`{"code": ..., "error": ...}` on stdout, exit
/// code 0), exactly like `serve` — the caller branches on `code`.
pub fn request(args: &Args) -> Result<i32> {
    let json = args.opt("json").map(str::to_string);
    args.reject_unknown()?;

    let engine = Engine::analytics();
    match json {
        Some(line) => {
            let (reply, _) = engine.handle_line(&line);
            println!("{reply}");
        }
        None => {
            let stdin = std::io::stdin();
            for line in stdin.lock().lines() {
                let line = line?;
                if line.trim().is_empty() {
                    continue;
                }
                let (reply, stop) = engine.handle_line(&line);
                println!("{reply}");
                if stop {
                    break;
                }
            }
        }
    }
    Ok(0)
}
