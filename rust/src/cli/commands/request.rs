//! `request`: one-shot protocol dispatch — the `serve` wire protocol
//! without a socket. Reads JSON request lines (from `--json` or stdin),
//! dispatches each through [`crate::api::Engine::handle_line`] and prints
//! the JSON replies. Used by the CI protocol-golden smoke step and handy
//! for scripting (`printf '{"cmd":"version"}' | psim request`).
//!
//! Runs on an analytics-only engine — deliberately: replies stay
//! byte-deterministic regardless of whether `artifacts/` exists (the CI
//! fixtures depend on that), and a version query never pays a model
//! load. Inference requests report `inference_unavailable`; use
//! `psim serve` / `psim client` for the PJRT path.

use std::io::BufRead;
use std::path::Path;

use anyhow::{Context, Result};

use crate::api::Engine;
use crate::cli::args::Args;
use crate::store::{ResultStore, DEFAULT_CAPACITY as DEFAULT_STORE_CAPACITY};

/// `psim request [--json LINE] [--store DIR]`
///
/// Errors are replies too (`{"code": ..., "error": ...}` on stdout, exit
/// code 0), exactly like `serve` — the caller branches on `code`.
/// `--store DIR` attaches the content-addressed result store, so a
/// repeated analytics request replays the reply another process (or a
/// previous invocation) already computed.
pub fn request(args: &Args) -> Result<i32> {
    let json = args.opt("json").map(str::to_string);
    let store_dir = args.opt("store").map(str::to_string);
    args.reject_unknown()?;

    let engine = Engine::analytics();
    if let Some(dir) = &store_dir {
        let store =
            ResultStore::open(Path::new(dir), DEFAULT_STORE_CAPACITY, engine.registry())
                .with_context(|| format!("opening result store '{dir}'"))?;
        engine.attach_store(store);
    }
    match json {
        Some(line) => {
            let (reply, _) = engine.handle_line(&line);
            println!("{reply}");
        }
        None => {
            let stdin = std::io::stdin();
            for line in stdin.lock().lines() {
                let line = line?;
                if line.trim().is_empty() {
                    continue;
                }
                let (reply, stop) = engine.handle_line(&line);
                println!("{reply}");
                if stop {
                    break;
                }
            }
        }
    }
    Ok(0)
}
