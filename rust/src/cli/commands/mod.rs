//! Command implementations, one module per command family.

pub mod analyze;
pub mod bench;
pub mod cache;
pub mod explore;
pub mod fusion;
pub mod infer;
pub mod lint;
pub mod request;
pub mod serve;
pub mod simulate;
pub mod stats;
pub mod sweep;
pub mod tables;
pub mod zoo;
