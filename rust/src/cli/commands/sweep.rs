//! `sweep`: the unified scenario-sweep engine from the CLI — the paper's
//! full evaluation grid (or any slice of it) as deterministic JSONL.

use std::time::Instant;

use anyhow::{anyhow, Context, Result};

use crate::analytics::grid::SweepSpec;
use crate::api::engine::effective_workers;
use crate::api::{Engine, Request, Response};
use crate::cli::args::Args;
use crate::config::accel::{parse_mode, parse_strategy};
use crate::models::zoo;
use crate::models::Network;

/// Resolve one `--networks` entry. With `--faithful`, the eight faithful
/// architectures shadow their paper-profile namesakes (so
/// `--faithful --networks resnet50` really is grouped ResNeXt-50);
/// anything else falls back to the general zoo lookup. Shared with the
/// `explore` command.
pub(crate) fn resolve_network(name: &str, faithful: bool) -> Result<Network> {
    if faithful {
        if let Some(net) = zoo::faithful_by_name(name) {
            return Ok(net);
        }
    }
    zoo::by_name(name).ok_or_else(|| anyhow!("unknown network '{name}' — see `psim networks`"))
}

/// `psim sweep [--networks a,b] [--macs 512,...] [--strategies s1,s2]
/// [--modes passive,active] [--batches 1,8] [--fusion-depth 1,2]
/// [--bits 8:8:32:8,...] [--workers N] [--filter SUBSTR] [--out FILE]
/// [--faithful]`
///
/// `--bits` adds a per-tensor precision axis
/// (`ifmap:weight:psum:ofmap` bits, comma-separated for several, presets
/// `int8`/`fp16`); non-default precisions add byte-weighted keys to each
/// record and re-derive `optimal`/`search` partitions under byte
/// weighting (see `docs/MODEL.md`).
///
/// Emits one JSON object per grid cell (JSONL) on stdout (or `--out`),
/// byte-identical for any `--workers` value; a run summary goes to stderr
/// so stdout stays pipeable.
pub fn sweep(args: &Args) -> Result<i32> {
    let faithful = args.flag("faithful");
    let networks = match args.opt("networks") {
        Some(list) => list
            .split(',')
            .map(|raw| resolve_network(raw.trim(), faithful))
            .collect::<Result<Vec<_>>>()?,
        None => {
            if faithful {
                zoo::faithful_networks()
            } else {
                zoo::paper_networks()
            }
        }
    };
    let mut spec = SweepSpec::new(networks);
    if let Some(macs) = args.opt_usize_list("macs")? {
        spec.mac_budgets = macs;
    }
    if let Some(list) = args.opt("strategies").or_else(|| args.opt("strategy")) {
        spec.strategies =
            list.split(',').map(|s| parse_strategy(s.trim())).collect::<Result<Vec<_>>>()?;
    }
    if let Some(list) = args.opt("modes").or_else(|| args.opt("mode")) {
        spec.modes = list.split(',').map(|s| parse_mode(s.trim())).collect::<Result<Vec<_>>>()?;
    }
    if let Some(batches) = args.opt_usize_list("batches")? {
        spec.batch_sizes = batches;
    }
    if let Some(depths) = args.opt_usize_list("fusion-depth")? {
        spec.fusion_depths = depths;
    }
    if let Some(list) = args.opt("bits") {
        spec.datatypes = list
            .split(',')
            .map(crate::models::DataTypes::parse)
            .collect::<Result<Vec<_>>>()?;
    }
    let workers = effective_workers(args.opt_usize("workers")?);
    let filter = args.opt("filter").map(|f| f.to_ascii_lowercase());
    let out = args.opt("out").map(std::path::PathBuf::from);
    args.reject_unknown()?;

    // Same facade as `serve` and library callers: validation, the
    // request-size cap and the worker clamp all live in the dispatcher.
    let engine = Engine::analytics();
    let t0 = Instant::now();
    let resp = engine.dispatch(&Request::Sweep { spec, workers: Some(workers) })?;
    let elapsed = t0.elapsed();
    let Response::Sweep { grid, .. } = resp else {
        unreachable!("sweep dispatch always returns a sweep response")
    };

    let mut jsonl = String::new();
    let mut kept = 0usize;
    for cell in &grid.cells {
        let keep = match &filter {
            Some(f) => cell.key().to_ascii_lowercase().contains(f.as_str()),
            None => true,
        };
        if keep {
            jsonl.push_str(&cell.to_json().to_string());
            jsonl.push('\n');
            kept += 1;
        }
    }

    match &out {
        Some(path) => {
            std::fs::write(path, &jsonl)
                .with_context(|| format!("writing sweep output to {}", path.display()))?;
        }
        None => print!("{jsonl}"),
    }
    let (hits, misses) = engine.cache_stats();
    eprintln!(
        "sweep: {} cells ({kept} emitted{}) in {:.3}s on {workers} workers; \
         layer cache {hits} hits / {misses} misses",
        grid.len(),
        out.as_ref().map(|p| format!(" -> {}", p.display())).unwrap_or_default(),
        elapsed.as_secs_f64(),
    );
    Ok(0)
}
