//! Paper-table regenerators: `table1|table2|table3|fig2|validate`.
//!
//! `table1`..`fig2` round-trip through the typed facade
//! ([`Request::Tables`] → [`Engine::dispatch`]) — the same path the
//! protocol's `{"cmd":"tables"}` request takes; `validate` stays a local
//! report (it compares against the published numbers, a dev-time check).

use anyhow::Result;

use crate::api::{Engine, Request, Response, TableKind};
use crate::cli::args::Args;
use crate::report::compare;
use crate::util::tablefmt::Table;

fn emit(t: &Table, csv: bool) {
    if csv {
        print!("{}", t.to_csv());
    } else {
        print!("{}", t.to_markdown());
    }
}

fn faithful_note(args: &Args) -> bool {
    // --faithful switches to the architecturally faithful zoo; the default
    // is the calibrated paper profile (see models::zoo docs).
    args.flag("faithful")
}

/// Dispatch one table request and render the reply.
fn run_table(table: TableKind, faithful: bool, csv: bool) -> Result<i32> {
    let engine = Engine::analytics();
    match engine.dispatch(&Request::Tables { table, faithful })? {
        Response::Table { table, .. } => emit(&table, csv),
        Response::Text { text } => print!("{text}"),
        _ => unreachable!("tables dispatch returns a table or text response"),
    }
    Ok(0)
}

/// `psim table1 [--csv] [--faithful]` — paper Table I.
pub fn table1(args: &Args) -> Result<i32> {
    let csv = args.flag("csv");
    let faithful = faithful_note(args);
    args.reject_unknown()?;
    run_table(TableKind::Table1, faithful, csv)
}

/// `psim table2 [--csv] [--faithful]` — paper Table II.
pub fn table2(args: &Args) -> Result<i32> {
    let csv = args.flag("csv");
    let faithful = faithful_note(args);
    args.reject_unknown()?;
    run_table(TableKind::Table2, faithful, csv)
}

/// `psim table3 [--csv] [--faithful]` — paper Table III.
pub fn table3(args: &Args) -> Result<i32> {
    let csv = args.flag("csv");
    let faithful = faithful_note(args);
    args.reject_unknown()?;
    run_table(TableKind::Table3, faithful, csv)
}

/// `psim fig2 [--csv] [--ascii]` — paper Fig. 2.
pub fn fig2(args: &Args) -> Result<i32> {
    let csv = args.flag("csv");
    let ascii = args.flag("ascii");
    args.reject_unknown()?;
    run_table(if ascii { TableKind::Fig2Ascii } else { TableKind::Fig2 }, false, csv)
}

/// `psim validate [--full] [--csv]` — compare every cell against the paper.
pub fn validate(args: &Args) -> Result<i32> {
    let full = args.flag("full");
    let csv = args.flag("csv");
    args.reject_unknown()?;
    let cells = compare::compare_all();
    let s = compare::summarize(&cells);
    println!(
        "compared {} cells against the paper: median |Δ| {:.1}%, mean {:.1}%, \
         {} within 5%, {} within 15%, worst {:.1}%",
        s.cells,
        s.median_rel_diff * 100.0,
        s.mean_rel_diff * 100.0,
        s.within_5pct,
        s.within_15pct,
        s.worst * 100.0
    );
    for t in ["III", "II", "I"] {
        let sub: Vec<_> = cells.iter().filter(|c| c.table == t).cloned().collect();
        let ss = compare::summarize(&sub);
        println!(
            "  Table {t:>3}: median {:.1}%  worst {:.1}%  ({} cells)",
            ss.median_rel_diff * 100.0,
            ss.worst * 100.0,
            ss.cells
        );
    }
    if full {
        emit(&compare::to_table(&cells, true), csv);
    } else {
        println!("\nworst 10 cells (see EXPERIMENTS.md §Calibration for the why):");
        let t = compare::to_table(&cells, true);
        for line in t.to_markdown().lines().take(12) {
            println!("{line}");
        }
    }
    Ok(0)
}
