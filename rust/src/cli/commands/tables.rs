//! Paper-table regenerators: `table1|table2|table3|fig2|validate`.

use anyhow::Result;

use crate::cli::args::Args;
use crate::report::{compare, fig2 as fig2_mod, tables};
use crate::util::tablefmt::Table;

fn emit(t: &Table, csv: bool) {
    if csv {
        print!("{}", t.to_csv());
    } else {
        print!("{}", t.to_markdown());
    }
}

fn faithful_note(args: &Args) -> bool {
    // --faithful switches to the architecturally faithful zoo; the default
    // is the calibrated paper profile (see models::zoo docs).
    args.flag("faithful")
}

pub fn table1(args: &Args) -> Result<i32> {
    let csv = args.flag("csv");
    let faithful = faithful_note(args);
    args.reject_unknown()?;
    if faithful {
        emit(&tables::table1_for(&crate::models::zoo::faithful_networks()), csv);
    } else {
        emit(&tables::table1(), csv);
    }
    Ok(0)
}

pub fn table2(args: &Args) -> Result<i32> {
    let csv = args.flag("csv");
    let faithful = faithful_note(args);
    args.reject_unknown()?;
    if faithful {
        emit(&tables::table2_for(&crate::models::zoo::faithful_networks()), csv);
    } else {
        emit(&tables::table2(), csv);
    }
    Ok(0)
}

pub fn table3(args: &Args) -> Result<i32> {
    let csv = args.flag("csv");
    let faithful = faithful_note(args);
    args.reject_unknown()?;
    if faithful {
        emit(&tables::table3_for(&crate::models::zoo::faithful_networks()), csv);
    } else {
        emit(&tables::table3(), csv);
    }
    Ok(0)
}

pub fn fig2(args: &Args) -> Result<i32> {
    let csv = args.flag("csv");
    let ascii = args.flag("ascii");
    args.reject_unknown()?;
    if ascii {
        print!("{}", fig2_mod::fig2_ascii());
    } else {
        emit(&fig2_mod::fig2_table(), csv);
    }
    Ok(0)
}

pub fn validate(args: &Args) -> Result<i32> {
    let full = args.flag("full");
    let csv = args.flag("csv");
    args.reject_unknown()?;
    let cells = compare::compare_all();
    let s = compare::summarize(&cells);
    println!(
        "compared {} cells against the paper: median |Δ| {:.1}%, mean {:.1}%, \
         {} within 5%, {} within 15%, worst {:.1}%",
        s.cells,
        s.median_rel_diff * 100.0,
        s.mean_rel_diff * 100.0,
        s.within_5pct,
        s.within_15pct,
        s.worst * 100.0
    );
    for t in ["III", "II", "I"] {
        let sub: Vec<_> = cells.iter().filter(|c| c.table == t).cloned().collect();
        let ss = compare::summarize(&sub);
        println!(
            "  Table {t:>3}: median {:.1}%  worst {:.1}%  ({} cells)",
            ss.median_rel_diff * 100.0,
            ss.worst * 100.0,
            ss.cells
        );
    }
    if full {
        emit(&compare::to_table(&cells, true), csv);
    } else {
        println!("\nworst 10 cells (see EXPERIMENTS.md §Calibration for the why):");
        let t = compare::to_table(&cells, true);
        for line in t.to_markdown().lines().take(12) {
            println!("{line}");
        }
    }
    Ok(0)
}
