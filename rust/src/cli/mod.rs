//! The `psim` command surface.
//!
//! Paper regenerators: `table1`, `table2`, `table3`, `fig2`, `validate`.
//! Exploration: `analyze`, `simulate`, `sweep`, `networks`, `zoo`.
//! Functional stack: `infer` (batched PJRT inference), `serve` (TCP
//! JSON-lines server with a bounded worker pool), `bench` (protocol-level
//! load generator against `serve`), `stats` (one-shot observability
//! snapshot of a running server), `client` (legacy inference-only load
//! generator). Repo tooling: `lint` (static analyzer), `cache`
//! (result-store artifact inspection: ls/verify/gc).

pub mod args;
pub mod commands;

use anyhow::{bail, Result};
use args::Args;

const HELP: &str = "\
psim — partial-sum bandwidth analytics, accelerator simulator and serving
       stack reproducing Chandra, 'On the Impact of Partial Sums on
       Interconnect Bandwidth and Memory Accesses in a DNN Accelerator'
       (ICIIS 2020).

USAGE: psim <command> [options]

Paper evaluation (Section IV):
  table1              BW by partitioning strategy x P (Table I)
  table2              passive vs active controller x P (Table II)
  table3              minimum BW per network (Table III)
  fig2                % saving of the active controller (Fig. 2)
  validate            compare every cell against the published numbers
     options: --csv            emit CSV instead of markdown
              --faithful       use faithful architectures (see DESIGN.md)
              --full           (validate) print every cell, not a summary

Exploration:
  networks            list the model zoo with layer/MAC/BW summaries
  zoo                 operator-aware zoo listing: per-op kind counts
                      (conv/gemm/attention), MACs, true params,
                      activation totals
     options: [--csv]
  analyze             per-layer partitions + bandwidth for one network
     options: --network NAME --macs P [--strategy S] [--mode M]
  simulate            run the event-level simulator, cross-check analytics
     options: --network NAME [--macs P] [--strategy S] [--mode M]
              [--config FILE] [--trace]
  sweep               unified design-space sweep engine -> JSONL
                      (default: the full paper grid, 8 networks x 6 MAC
                      budgets x 4 strategies x 2 controller modes)
     options: [--networks a,b,c] [--macs 512,1024,...]
              [--strategies s1,s2] [--modes passive,active]
              [--batches 1,8] [--fusion-depth 1,2] [--workers N]
              [--filter SUBSTR] [--out FILE] [--faithful]
  fusion              fused-vs-unfused bandwidth per network: chains of
                      up to --depth consecutive layers keep their
                      intermediates on chip
     options: [--networks a,b,c] [--depth N] [--macs P] [--strategy S]
              [--mode passive|active] [--csv] [--faithful]
  simsweep            simulator-backed bulk sweep to CSV (adds energy,
                      cycles and MAC utilization per cell)
     options: [--networks a,b,c] [--macs 512,1024,...] [--strategy S]
              [--mode M]
  explore             design-space explorer -> Pareto frontier JSONL
                      over MAC budget x SRAM capacity x strategy x
                      controller mode, scored on (bandwidth, SRAM
                      accesses, energy, MAC utilization); closed-form
                      bound pruning, per network + whole-zoo frontiers
     options: [--networks a,b,c]
              [--constraints macs=512:2048,sram=64k:unlimited,
                             strategies=optimal:search,modes=active,
                             fusion=1:2]
              [--objectives bandwidth,energy,...] [--fusion [D]]
              [--workers N] [--out FILE] [--table] [--faithful]

Functional stack (PJRT over artifacts/; run `make artifacts` first):
  infer               batched PsimNet inference benchmark
     options: [--requests N] [--concurrency C] [--max-batch B] [--seed S]
  serve               TCP JSON-lines server: inference + design-space
                      queries ({\"cmd\":\"sweep\", ...}); runs without
                      artifacts in analytics-only mode; bounded worker
                      pool sheds load with code:\"too_busy\" when
                      saturated (--port 0 picks an ephemeral port);
                      --store DIR memoizes analytics replies in a
                      content-addressed artifact directory
     options: [--port P] [--max-batch B] [--workers N] [--queue N]
              [--max-conns N] [--timeout-ms MS] [--store DIR]
  bench               protocol-level load generator against a running
                      server; prints a JSON summary (throughput, p50/
                      p95/p99 latency, shed count) -- the
                      BENCH_serve.json schema
     options: [--port P] [--clients C] [--requests N] [--duration SECS]
              [--mix sweep,explore,version] [--out FILE] [--stats]
  stats               one-shot {\"cmd\":\"stats\"} snapshot of a running
                      server: JSON to stdout, human digest to stderr
     options: [--port P]
  client              legacy inference-only load generator
     options: [--port P] [--requests N]
  request             one-shot protocol dispatch: decode JSON request
                      lines (--json or stdin), print the JSON replies --
                      the serve protocol without a socket
                      (analytics-only engine; inference needs `serve`)
     options: [--json LINE] [--store DIR]

Repo tooling:
  cache               inspect a --store result-store artifact directory:
                      `ls` lists every artifact (digest, validity, size,
                      command), `verify` exits 1 if any artifact fails
                      validation, `gc` deletes invalid artifacts
     usage: psim cache <ls|verify|gc> --store DIR
  lint                run the psim-lint static analyzer over the repo
                      (panic freedom, overflow surface, catalog/protocol
                      sync, format gate, orphan goldens -- docs/LINTS.md);
                      exit 1 on any non-allowlisted finding
     options: [--json] [--fix-hints] [--root DIR]

  version             crate + protocol version (also: psim --version)
  help                this text
";

/// Entry point used by main(); returns the process exit code.
pub fn run(argv: &[String]) -> Result<i32> {
    // `cache` takes an action token (`psim cache ls ...`) the flag-only
    // parser would reject as a positional, so it is routed first.
    if argv.first().map(String::as_str) == Some("cache") {
        return commands::cache::cache(&argv[1..]);
    }
    let args = Args::parse(argv)?;
    match args.command.as_str() {
        "" | "help" | "--help" | "-h" => {
            print!("{HELP}");
            Ok(0)
        }
        "version" | "--version" | "-V" => {
            args.reject_unknown()?;
            println!("{}", crate::api::version_line());
            Ok(0)
        }
        "table1" => commands::tables::table1(&args),
        "table2" => commands::tables::table2(&args),
        "table3" => commands::tables::table3(&args),
        "fig2" => commands::tables::fig2(&args),
        "validate" => commands::tables::validate(&args),
        "networks" => commands::analyze::networks(&args),
        "zoo" => commands::zoo::zoo(&args),
        "analyze" => commands::analyze::analyze(&args),
        "simulate" => commands::simulate::simulate(&args),
        "simsweep" => commands::simulate::simsweep(&args),
        "sweep" => commands::sweep::sweep(&args),
        "explore" => commands::explore::explore(&args),
        "fusion" => commands::fusion::fusion(&args),
        "infer" => commands::infer::infer(&args),
        "serve" => commands::serve::serve(&args),
        "bench" => commands::bench::bench(&args),
        "stats" => commands::stats::stats(&args),
        "client" => commands::serve::client(&args),
        "request" => commands::request::request(&args),
        "lint" => commands::lint::lint(&args),
        other => bail!("unknown command '{other}' — try `psim help`"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(parts: &[&str]) -> Vec<String> {
        parts.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn help_runs() {
        assert_eq!(run(&sv(&["help"])).unwrap(), 0);
        assert_eq!(run(&sv(&[])).unwrap(), 0);
    }

    #[test]
    fn unknown_command_errors() {
        assert!(run(&sv(&["frobnicate"])).is_err());
    }

    #[test]
    fn version_runs_in_both_spellings() {
        assert_eq!(run(&sv(&["version"])).unwrap(), 0);
        assert_eq!(run(&sv(&["--version"])).unwrap(), 0);
        assert_eq!(run(&sv(&["-V"])).unwrap(), 0);
        assert!(run(&sv(&["version", "--frobnicate"])).is_err());
    }

    #[test]
    fn cache_routes_through_the_action_parser() {
        // The action token would be an illegal positional for Args; the
        // router must hand it to the cache command instead.
        assert!(run(&sv(&["cache"])).is_err());
        assert!(run(&sv(&["cache", "frobnicate"])).is_err());
        assert!(run(&sv(&["cache", "ls"])).is_err(), "--store is required");
        let dir = std::env::temp_dir().join(format!("psim_cli_cache_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        assert_eq!(run(&sv(&["cache", "ls", "--store", dir.to_str().unwrap()])).unwrap(), 0);
        assert_eq!(run(&sv(&["cache", "verify", "--store", dir.to_str().unwrap()])).unwrap(), 0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn request_store_warms_across_processes() {
        let dir = std::env::temp_dir().join(format!("psim_cli_reqstore_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let line = r#"{"cmd":"tables","table":"table3"}"#;
        for _ in 0..2 {
            // Each run is a fresh engine: the second can only hit disk.
            assert_eq!(
                run(&sv(&["request", "--json", line, "--store", dir.to_str().unwrap()]))
                    .unwrap(),
                0
            );
        }
        assert_eq!(run(&sv(&["cache", "verify", "--store", dir.to_str().unwrap()])).unwrap(), 0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn request_dispatches_one_shot_lines() {
        // Replies (including error replies) go to stdout; exit code stays
        // 0 like a serve connection. Unknown flags still fail.
        assert_eq!(run(&sv(&["request", "--json", r#"{"cmd":"version"}"#])).unwrap(), 0);
        assert_eq!(run(&sv(&["request", "--json", "not json"])).unwrap(), 0);
        assert!(run(&sv(&["request", "--frobnicate"])).is_err());
    }

    #[test]
    fn tables_run() {
        for cmd in ["table1", "table2", "table3", "fig2", "validate"] {
            assert_eq!(run(&sv(&[cmd])).unwrap(), 0, "{cmd}");
        }
    }

    #[test]
    fn analyze_requires_network() {
        assert!(run(&sv(&["analyze"])).is_err());
        assert_eq!(run(&sv(&["analyze", "--network", "AlexNet", "--macs", "512"])).unwrap(), 0);
    }

    #[test]
    fn simulate_cross_checks_model() {
        // exit code 0 == sim matched the analytical model exactly
        assert_eq!(
            run(&sv(&["simulate", "--network", "resnet18", "--macs", "1024", "--mode", "active"]))
                .unwrap(),
            0
        );
    }

    #[test]
    fn simulate_trace_runs() {
        // --trace dumps ring-buffer excerpts + dropped counts and must
        // not disturb the sim-vs-model cross-check (exit code 0).
        assert_eq!(
            run(&sv(&["simulate", "--network", "AlexNet", "--macs", "512", "--trace"])).unwrap(),
            0
        );
    }

    #[test]
    fn simulate_accepts_extension_networks() {
        assert_eq!(
            run(&sv(&["simulate", "--network", "resnet34", "--macs", "2048"])).unwrap(),
            0
        );
    }

    #[test]
    fn zoo_runs_and_rejects_unknown_flags() {
        assert_eq!(run(&sv(&["zoo"])).unwrap(), 0);
        assert_eq!(run(&sv(&["zoo", "--csv"])).unwrap(), 0);
        assert!(run(&sv(&["zoo", "--frobnicate"])).is_err());
    }

    #[test]
    fn sweep_and_networks_run() {
        assert_eq!(run(&sv(&["networks"])).unwrap(), 0);
        assert_eq!(
            run(&sv(&["sweep", "--networks", "AlexNet", "--macs", "512,2048"])).unwrap(),
            0
        );
    }

    #[test]
    fn simsweep_runs() {
        assert_eq!(
            run(&sv(&["simsweep", "--networks", "AlexNet", "--macs", "512,2048"])).unwrap(),
            0
        );
        assert!(run(&sv(&["simsweep", "--networks", "NoSuchNet"])).is_err());
    }

    #[test]
    fn sweep_grid_flags() {
        assert_eq!(
            run(&sv(&[
                "sweep",
                "--networks",
                "AlexNet",
                "--macs",
                "512",
                "--strategies",
                "optimal,max-input",
                "--modes",
                "active",
                "--batches",
                "1,8",
                "--workers",
                "2",
                "--filter",
                "optimal",
            ]))
            .unwrap(),
            0
        );
        assert!(run(&sv(&["sweep", "--strategies", "voodoo"])).is_err());
        assert!(run(&sv(&["sweep", "--networks", "NoSuchNet"])).is_err());
        assert!(run(&sv(&["sweep", "--macs", "0"])).is_err());
        // --faithful composes with --networks (resolves the faithful zoo)
        assert_eq!(
            run(&sv(&[
                "sweep",
                "--faithful",
                "--networks",
                "resnet50,MNASNet",
                "--macs",
                "512",
                "--strategies",
                "optimal",
                "--modes",
                "passive",
            ]))
            .unwrap(),
            0
        );
    }

    #[test]
    fn sweep_fusion_depth_flag() {
        assert_eq!(
            run(&sv(&[
                "sweep",
                "--networks",
                "AlexNet",
                "--macs",
                "512",
                "--strategies",
                "optimal",
                "--modes",
                "passive",
                "--fusion-depth",
                "1,2",
            ]))
            .unwrap(),
            0
        );
        assert!(run(&sv(&["sweep", "--fusion-depth", "0"])).is_err());
        assert!(run(&sv(&["sweep", "--fusion-depth", "deep"])).is_err());
    }

    #[test]
    fn fusion_command_runs() {
        assert_eq!(run(&sv(&["fusion", "--networks", "AlexNet", "--depth", "2"])).unwrap(), 0);
        assert_eq!(run(&sv(&["fusion", "--csv", "--macs", "2048"])).unwrap(), 0);
        assert!(run(&sv(&["fusion", "--networks", "NoSuchNet"])).is_err());
        assert!(run(&sv(&["fusion", "--strategy", "voodoo"])).is_err());
        assert!(run(&sv(&["fusion", "--depth", "0"])).is_err());
        assert!(run(&sv(&["fusion", "--macs", "0"])).is_err());
        assert!(run(&sv(&["fusion", "--frobnicate"])).is_err());
    }

    #[test]
    fn explore_fusion_flag() {
        assert_eq!(
            run(&sv(&[
                "explore",
                "--networks",
                "AlexNet",
                "--fusion",
                "--constraints",
                "macs=1024,sram=unlimited,strategies=optimal,modes=active",
            ]))
            .unwrap(),
            0
        );
        assert_eq!(
            run(&sv(&[
                "explore",
                "--networks",
                "AlexNet",
                "--fusion",
                "3",
                "--constraints",
                "macs=1024,sram=unlimited,strategies=optimal,modes=active",
            ]))
            .unwrap(),
            0
        );
        assert!(run(&sv(&["explore", "--networks", "AlexNet", "--fusion", "0"])).is_err());
    }

    #[test]
    fn explore_flags() {
        assert_eq!(
            run(&sv(&[
                "explore",
                "--networks",
                "AlexNet",
                "--constraints",
                "macs=512:1024,sram=unlimited:64k,strategies=optimal,modes=active",
                "--objectives",
                "bandwidth,energy",
                "--workers",
                "2",
            ]))
            .unwrap(),
            0
        );
        assert_eq!(run(&sv(&["explore", "--networks", "AlexNet", "--table"])).unwrap(), 0);
        assert!(run(&sv(&["explore", "--networks", "NoSuchNet"])).is_err());
        assert!(run(&sv(&["explore", "--constraints", "volts=3"])).is_err());
        assert!(run(&sv(&["explore", "--objectives", "latency"])).is_err());
        assert!(run(&sv(&["explore", "--frobnicate"])).is_err());
    }

    #[test]
    fn explore_out_writes_frontier_jsonl() {
        let path = std::env::temp_dir().join("psim_cli_explore_out.jsonl");
        let _ = std::fs::remove_file(&path);
        assert_eq!(
            run(&sv(&[
                "explore",
                "--networks",
                "AlexNet",
                "--constraints",
                "macs=1024,sram=unlimited",
                "--out",
                path.to_str().unwrap(),
            ]))
            .unwrap(),
            0
        );
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(!text.is_empty());
        assert!(text.lines().all(|l| l.contains("\"network\":\"AlexNet\"")));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn sweep_out_writes_jsonl() {
        let path = std::env::temp_dir().join("psim_cli_sweep_out.jsonl");
        let _ = std::fs::remove_file(&path);
        assert_eq!(
            run(&sv(&[
                "sweep",
                "--networks",
                "AlexNet",
                "--macs",
                "512,2048",
                "--strategies",
                "optimal",
                "--modes",
                "passive",
                "--out",
                path.to_str().unwrap(),
            ]))
            .unwrap(),
            0
        );
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count(), 2);
        assert!(text.contains("\"network\":\"AlexNet\""));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn unknown_flags_are_rejected_per_command() {
        assert!(run(&sv(&["table1", "--frobnicate"])).is_err());
        assert!(run(&sv(&["simulate", "--network", "AlexNet", "--warp", "9"])).is_err());
    }

    #[test]
    fn bench_rejects_bad_flags_and_mixes_before_connecting() {
        // Both fail during argument validation, so no server is needed.
        assert!(run(&sv(&["bench", "--frobnicate"])).is_err());
        assert!(run(&sv(&["bench", "--mix", "frobnicate"])).is_err());
    }

    #[test]
    fn stats_rejects_bad_flags_and_fails_without_a_server() {
        assert!(run(&sv(&["stats", "--frobnicate"])).is_err());
        // Port 1 is never listening in the test environment.
        assert!(run(&sv(&["stats", "--port", "1"])).is_err());
    }

    #[test]
    fn faithful_and_csv_variants() {
        assert_eq!(run(&sv(&["table3", "--faithful"])).unwrap(), 0);
        assert_eq!(run(&sv(&["table2", "--csv"])).unwrap(), 0);
        assert_eq!(run(&sv(&["fig2", "--ascii"])).unwrap(), 0);
    }

    #[test]
    fn bad_strategy_or_mode_errors() {
        assert!(run(&sv(&["analyze", "--network", "AlexNet", "--strategy", "voodoo"])).is_err());
        assert!(run(&sv(&["simulate", "--network", "AlexNet", "--mode", "quantum"])).is_err());
    }
}
