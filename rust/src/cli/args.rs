//! Tiny argument parser: `psim <command> [--key value]... [--flag]...`.
//! (clap is not in the offline vendor set.)

use std::collections::BTreeMap;

use anyhow::{bail, Result};

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    /// The subcommand name (first positional token).
    pub command: String,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
    /// Option keys the command actually read (unknown-option detection).
    consumed: std::cell::RefCell<Vec<String>>,
}

impl Args {
    /// Parse `command [--key value|--key=value|--flag]...`.
    pub fn parse(argv: &[String]) -> Result<Args> {
        let mut args =
            Args { command: argv.first().cloned().unwrap_or_default(), ..Default::default() };
        let mut i = 1;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(key) = a.strip_prefix("--") {
                if key.is_empty() {
                    bail!("bare '--' not supported");
                }
                // --key=value or --key value or --flag
                if let Some((k, v)) = key.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    args.options.insert(key.to_string(), argv[i + 1].clone());
                    i += 1;
                } else {
                    args.flags.push(key.to_string());
                }
            } else {
                bail!("unexpected positional argument '{a}' (options start with --)");
            }
            i += 1;
        }
        Ok(args)
    }

    /// Value of option `--key`, if present.
    pub fn opt(&self, key: &str) -> Option<&str> {
        self.consumed.borrow_mut().push(key.to_string());
        self.options.get(key).map(|s| s.as_str())
    }

    /// Integer value of option `--key`, if present.
    pub fn opt_usize(&self, key: &str) -> Result<Option<usize>> {
        match self.opt(key) {
            None => Ok(None),
            Some(v) => v
                .parse::<usize>()
                .map(Some)
                .map_err(|_| anyhow::anyhow!("--{key} expects an integer, got '{v}'")),
        }
    }

    /// Comma-separated usize list.
    pub fn opt_usize_list(&self, key: &str) -> Result<Option<Vec<usize>>> {
        match self.opt(key) {
            None => Ok(None),
            Some(v) => v
                .split(',')
                .map(|p| {
                    p.trim()
                        .parse::<usize>()
                        .map_err(|_| anyhow::anyhow!("--{key}: bad integer '{p}'"))
                })
                .collect::<Result<Vec<_>>>()
                .map(Some),
        }
    }

    /// Whether bare flag `--key` was given.
    pub fn flag(&self, key: &str) -> bool {
        self.consumed.borrow_mut().push(key.to_string());
        self.flags.iter().any(|f| f == key)
    }

    /// Error on any option/flag the command never consulted.
    pub fn reject_unknown(&self) -> Result<()> {
        let consumed = self.consumed.borrow();
        for k in self.options.keys() {
            if !consumed.contains(k) {
                bail!("unknown option --{k} for '{}'", self.command);
            }
        }
        for f in &self.flags {
            if !consumed.contains(f) {
                bail!("unknown flag --{f} for '{}'", self.command);
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(parts: &[&str]) -> Vec<String> {
        parts.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_command_options_flags() {
        let a = Args::parse(&sv(&["simulate", "--network", "AlexNet", "--macs=2048", "--trace"]))
            .unwrap();
        assert_eq!(a.command, "simulate");
        assert_eq!(a.opt("network"), Some("AlexNet"));
        assert_eq!(a.opt_usize("macs").unwrap(), Some(2048));
        assert!(a.flag("trace"));
        assert!(!a.flag("csv"));
        a.reject_unknown().unwrap();
    }

    #[test]
    fn usize_list() {
        let a = Args::parse(&sv(&["sweep", "--macs", "512,1024, 2048"])).unwrap();
        assert_eq!(a.opt_usize_list("macs").unwrap(), Some(vec![512, 1024, 2048]));
    }

    #[test]
    fn rejects_unknown_options() {
        let a = Args::parse(&sv(&["table1", "--bogus", "1"])).unwrap();
        let _ = a.flag("csv");
        assert!(a.reject_unknown().is_err());
    }

    #[test]
    fn rejects_positional() {
        assert!(Args::parse(&sv(&["run", "file.txt"])).is_err());
    }

    #[test]
    fn bad_integer_reported() {
        let a = Args::parse(&sv(&["x", "--macs", "lots"])).unwrap();
        assert!(a.opt_usize("macs").is_err());
    }
}
